"""The prepared-data plane (DESIGN.md §3.3): fingerprinted device-resident
dataset cache, parameterized converters, conversion-aware scheduling.

Covers: fingerprint stability, converter-param cache keying, in-flight
build de-duplication, fused+sequential paths sharing one entry, per-slice
mesh placement reuse, the WAL/CostModel conversion accounting that used to
vanish, and the acceptance criterion — a 64-config gbdt grid converts
exactly once per (dataset-fingerprint, max_bins) pair.
"""
import threading

import numpy as np
import pytest

import repro.tabular  # noqa: F401  (registers the estimators)
from repro.core import (
    CostModel,
    DenseMatrix,
    LocalExecutorPool,
    MeshSliceExecutorPool,
    SearchSpec,
    Session,
    TrainTask,
    charge_first_of_group,
    convert,
    format_key,
    get_estimator,
    plan_makespan_estimate,
    prepare_cached,
    prepared_data_cache,
    register_converter,
    run_prepared,
    run_prepared_batched,
    schedule,
    unregister_converter,
)
from repro.core.data_format import PreparedDataCache, payload_nbytes
from repro.core.fusion import fuse_tasks
from repro.core.interface import Estimator


@pytest.fixture
def data():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(300, 6)).astype(np.float32)
    y = (x[:, 0] + x[:, 1] > 0).astype(np.float32)
    return DenseMatrix(x, y)


@pytest.fixture(autouse=True)
def _clean_global_cache():
    prepared_data_cache().clear()
    yield
    prepared_data_cache().clear()


# --------------------------------------------------------------------------
# Fingerprint.
# --------------------------------------------------------------------------

def test_fingerprint_stable_across_equal_content_copies(data):
    twin = DenseMatrix(data.x.copy(), data.y.copy(), data.feature_names)
    assert data.fingerprint() == twin.fingerprint()
    # memoized: second call returns the same string object
    assert data.fingerprint() is data.fingerprint()


def test_fingerprint_changes_with_content(data):
    x2 = data.x.copy()
    x2[0, 0] += 1.0
    assert DenseMatrix(x2, data.y).fingerprint() != data.fingerprint()
    assert DenseMatrix(data.x, 1.0 - data.y).fingerprint() != data.fingerprint()
    named = DenseMatrix(data.x, data.y, tuple("f" + str(i) for i in range(6)))
    assert named.fingerprint() != data.fingerprint()


# --------------------------------------------------------------------------
# Converter registry: params, unregister, idempotent re-registration.
# --------------------------------------------------------------------------

def test_parameterized_convert(data):
    q64 = convert(data, "quantized_bins", max_bins=64)
    q256 = convert(data, "quantized_bins")
    assert int(q64["n_bins"]) == 64
    assert int(q256["n_bins"]) == 256
    with pytest.raises(ValueError):
        convert(data, "quantized_bins", max_bins=1)


def test_format_key_canonical():
    assert format_key("dense_rows") == "dense_rows"
    assert format_key("quantized_bins", {"max_bins": 64}) == \
        "quantized_bins(max_bins=64)"
    # sorted items: dict order does not matter
    assert format_key("f", {"b": 2, "a": 1}) == format_key("f", {"a": 1, "b": 2})
    assert format_key("quantized_bins", {"max_bins": 64}) != \
        format_key("quantized_bins", {"max_bins": 256})


def test_unregister_and_idempotent_reregistration():
    def conv(d):
        return {"n": d.n_rows}

    register_converter("test-fmt")(conv)
    # same function again: no-op (hot reload / re-import)
    register_converter("test-fmt")(conv)

    def other(d):
        return {}

    with pytest.raises(ValueError):
        register_converter("test-fmt")(other)
    unregister_converter("test-fmt")
    register_converter("test-fmt")(other)   # name free again
    unregister_converter("test-fmt")
    unregister_converter("test-fmt")        # idempotent


# --------------------------------------------------------------------------
# CSR is actually CSR.
# --------------------------------------------------------------------------

def test_sparse_csr_roundtrip():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(40, 7)).astype(np.float32)
    x[rng.random(size=x.shape) < 0.6] = 0.0
    d = DenseMatrix(x, np.zeros(40))
    csr = convert(d, "sparse_csr")
    values = np.asarray(csr["values"])
    col_idx = np.asarray(csr["col_idx"])
    indptr = np.asarray(csr["indptr"])
    assert indptr[0] == 0 and indptr[-1] == len(values) == np.count_nonzero(x)
    dense = np.zeros(csr["shape"], np.float32)
    for r in range(x.shape[0]):
        lo, hi = indptr[r], indptr[r + 1]
        # within-row column indices strictly ascend (CSR canonical form)
        assert np.all(np.diff(col_idx[lo:hi]) > 0)
        dense[r, col_idx[lo:hi]] = values[lo:hi]
    np.testing.assert_array_equal(dense, x)


# --------------------------------------------------------------------------
# PreparedDataCache mechanics.
# --------------------------------------------------------------------------

def test_cache_keys_on_converter_params(data):
    cache = PreparedDataCache()
    a, s_a, built_a = prepare_cached(data, "quantized_bins", {"max_bins": 64},
                                     cache=cache)
    b, s_b, built_b = prepare_cached(data, "quantized_bins", {"max_bins": 256},
                                     cache=cache)
    c, s_c, built_c = prepare_cached(data, "quantized_bins", {"max_bins": 64},
                                     cache=cache)
    assert built_a and built_b and not built_c
    assert s_a > 0 and s_b > 0 and s_c == 0.0
    assert c is a and b is not a
    assert cache.counters() == (1, 2)
    assert cache.bytes_cached >= payload_nbytes(a)
    assert cache.n_entries == 2
    cache.clear()
    assert cache.counters() == (0, 0) and cache.bytes_cached == 0


def test_cache_shared_across_equal_content_copies(data):
    cache = PreparedDataCache()
    twin = DenseMatrix(data.x.copy(), data.y.copy())
    prepare_cached(data, "dense_rows", cache=cache)
    _, secs, built = prepare_cached(twin, "dense_rows", cache=cache)
    assert not built and secs == 0.0
    assert cache.counters() == (1, 1)


def test_cache_deduplicates_concurrent_builds(data):
    cache = PreparedDataCache()
    builds = []
    gate = threading.Event()

    def builder():
        builds.append(1)
        gate.wait(2.0)
        return {"x": np.zeros(4)}

    results = []

    def worker():
        results.append(cache.get("k", builder))

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    gate.set()
    for t in threads:
        t.join()
    assert len(builds) == 1                      # conversion ran EXACTLY once
    assert cache.counters() == (3, 1)
    assert sum(1 for _, _, built in results if built) == 1
    assert len({id(v) for v, _, _ in results}) == 1


def test_cache_failed_build_does_not_poison_key():
    cache = PreparedDataCache()
    with pytest.raises(RuntimeError):
        cache.get("k", lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    value, _, built = cache.get("k", lambda: {"ok": np.ones(2)})
    assert built and value["ok"].sum() == 2


# --------------------------------------------------------------------------
# run_prepared / run_prepared_batched: shared entries, convert_seconds.
# --------------------------------------------------------------------------

def test_fused_and_sequential_share_one_entry(data):
    cache = PreparedDataCache()
    est = get_estimator("gbdt")
    params = {"round": 3, "max_depth": 2, "max_bin": 32}
    model, train_s, conv_s = run_prepared(est, data, params, cache=cache)
    assert conv_s > 0 and train_s > 0
    configs = [dict(params, eta=e) for e in (0.1, 0.3)]
    models, _total, conv_b = run_prepared_batched(est, data, configs,
                                                  cache=cache)
    # the batch HIT the sequential path's entry: one conversion total
    assert conv_b == 0.0
    assert cache.counters() == (1, 1)
    # bit-identical data -> bit-identical margins for the matching config
    mb = models[1]
    np.testing.assert_array_equal(model.predict_proba(data.x),
                                  mb.predict_proba(data.x))


def test_prepare_override_is_honored_and_keyed_per_estimator(data):
    """A subclass's prepare() override IS what the executor path caches —
    under a key discriminated by estimator name, so it can't collide with
    other users of the same declared format."""
    from repro.core.interface import prepared_cache_key

    class Scaled(Estimator):
        name = "scaled-prepare"
        data_format = "dense_rows"

        def prepare(self, raw, params=None):
            return {"x": raw.x * 2.0, "y": raw.y}

        def train(self, d, params):
            return d["x"][0, 0]          # leak the prepared payload

    est = Scaled()
    cache = PreparedDataCache()
    model, _secs, conv = run_prepared(est, data, {}, cache=cache)
    assert conv > 0
    assert model == data.x[0, 0] * 2.0   # trained on the OVERRIDDEN payload
    # keyed apart from the plain dense_rows entry of standard estimators
    assert prepared_cache_key(est, data, {}) != \
        prepared_cache_key(get_estimator("logreg"), data, {})
    _, _, conv2 = run_prepared(est, data, {}, cache=cache)
    assert conv2 == 0.0 and cache.counters() == (1, 1)


def test_run_batched_rejects_mixed_formats(data):
    """A batch converts once, so mixed format params must fail loud instead
    of silently training some members on another config's layout."""
    est = get_estimator("gbdt")
    with pytest.raises(ValueError, match="format-uniform"):
        est.run_batched(data, [{"max_bin": 32, "round": 2, "max_depth": 2},
                               {"max_bin": 64, "round": 2, "max_depth": 2}])
    with pytest.raises(ValueError, match="format-uniform"):
        run_prepared_batched(est, data,
                             [{"max_bin": 32}, {"max_bin": 64}],
                             cache=PreparedDataCache())


def test_legacy_run_override_falls_back_uncached(data):
    class Legacy(Estimator):
        name = "legacy-override"

        def train(self, d, params):
            raise AssertionError("train must not be called via run()")

        def run(self, raw, params):
            return "legacy-model", 0.5

    cache = PreparedDataCache()
    model, secs, conv = run_prepared(Legacy(), data, {}, cache=cache)
    assert (model, secs, conv) == ("legacy-model", 0.5, 0.0)
    assert cache.counters() == (0, 0)            # bypassed entirely


def test_local_pool_reports_convert_seconds(data):
    tasks = [TrainTask(task_id=i, estimator="logreg",
                       params={"c": 0.1, "steps": 5}, cost=1.0)
             for i in range(3)]
    cache = PreparedDataCache()
    pool = LocalExecutorPool(1, prepared_cache=cache)
    results = pool.run(schedule(tasks, 1, policy="lpt"), data)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2]
    paid = [r for r in results if r.convert_seconds > 0]
    assert len(paid) == 1                        # only the builder paid
    assert cache.counters() == (2, 1)


# --------------------------------------------------------------------------
# Mesh pool: per-slice placement reuse via the estimator-backed default.
# --------------------------------------------------------------------------

def test_mesh_pool_per_slice_placement_reuse(data):
    cache = PreparedDataCache()
    pool = MeshSliceExecutorPool(slices=["s0", "s1"], prepared_cache=cache)
    tasks = [TrainTask(task_id=i, estimator="logreg",
                       params={"c": 0.1, "steps": 5}, cost=1.0)
             for i in range(6)]
    results = pool.run(schedule(tasks, 2, policy="lpt"), data)
    assert sorted(r.task.task_id for r in results) == list(range(6))
    assert all(r.ok for r in results)
    # one conversion PER SLICE (each slice holds its own resident copy),
    # every later task on the slice reuses it
    assert cache.counters() == (4, 2)
    assert sum(1 for r in results if r.convert_seconds > 0) == 2


def test_mesh_pool_default_runner_fused_batches(data):
    cache = PreparedDataCache()
    pool = MeshSliceExecutorPool(slices=["s0"], prepared_cache=cache)
    tasks = [TrainTask(task_id=i, estimator="logreg",
                       params={"c": 0.1 * (i + 1), "steps": 5}, cost=1.0)
             for i in range(4)]
    (unit,) = fuse_tasks(tasks, max_fuse=4)
    results = pool.run(schedule([unit], 1, policy="lpt"), data)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3]
    assert all(r.ok and r.batch_size == 4 for r in results)
    assert cache.counters() == (0, 1)
    # one build, one carrier: the FULL convert_seconds rides on exactly one
    # member (fusion.charge_carrier — where the planner puts the charge)
    assert sum(1 for r in results if r.convert_seconds > 0) == 1


# --------------------------------------------------------------------------
# Conversion law + conversion-aware scheduling.
# --------------------------------------------------------------------------

def test_cost_model_conversion_law_roundtrip(tmp_path):
    cm = CostModel(str(tmp_path / "cm.json"))
    key = format_key("quantized_bins", {"max_bins": 64})
    assert cm.predict_convert(key, 1000) is None
    cm.observe_convert(key, 0.5, 1000)
    cm.observe_convert(key, 1.0, 2000)
    p = cm.predict_convert(key, 1500)
    assert p is not None and 0.5 <= p <= 1.0
    # bigger data never predicts cheaper conversion
    assert cm.predict_convert(key, 4000) >= cm.predict_convert(key, 1000)
    cm.save()
    warm = CostModel.open(str(tmp_path / "cm.json"))
    assert warm.predict_convert(key, 1500) == pytest.approx(p)


def test_observe_result_feeds_conversion_law(data):
    cm = CostModel()
    task = TrainTask(task_id=0, estimator="gbdt",
                     params={"round": 3, "max_depth": 2, "max_bin": 32})
    from repro.core.interface import TaskResult

    cm.observe_result(TaskResult(task=task, model=object(), train_seconds=0.2,
                                 executor_id=0, convert_seconds=0.4),
                      data.n_rows)
    key = format_key("quantized_bins", {"max_bins": 32})
    assert cm.predict_convert(key, data.n_rows) == pytest.approx(0.4, rel=1e-6)
    # a cache-hit result (convert_seconds == 0) adds nothing
    cm.observe_result(TaskResult(task=task, model=object(), train_seconds=0.2,
                                 executor_id=0), data.n_rows)
    assert cm.predict_convert(key, data.n_rows) == pytest.approx(0.4, rel=1e-6)


def test_charge_first_of_group():
    tasks = [TrainTask(task_id=i, estimator="gbdt",
                       params={"max_bin": 32 if i < 2 else 64}, cost=float(i + 1))
             for i in range(4)]
    charged = charge_first_of_group(
        tasks,
        group_key=lambda t: t.params["max_bin"],
        extra_cost=lambda key: {32: 10.0, 64: None}[key])
    # the MAX-cost unit of the cold 32-bin group pays; unknown-cost group
    # (64) stays uncharged; everything else untouched
    assert [t.cost for t in charged] == [1.0, 12.0, 3.0, 4.0]
    # the charge flows into the plan's makespan estimate
    plan = schedule(charged, 2, policy="lpt")
    assert plan_makespan_estimate(plan) >= 12.0


def test_session_charges_cold_formats(data):
    """End-to-end: a warm conversion law + a cold cache => the first unit of
    each format group is costed with conversion included; a warm cache =>
    no charge."""
    cm = CostModel()
    key = format_key("quantized_bins", {"max_bins": 32})
    cm.observe_convert(key, 5.0, data.n_rows)
    spec = SearchSpec.from_dict({
        "spaces": [{"estimator": "gbdt", "grid": {"eta": [0.1, 0.3]}}],
        "n_executors": 1})
    session = Session(spec)
    tasks = [TrainTask(task_id=i, estimator="gbdt",
                       params={"max_bin": 32}, cost=1.0) for i in range(3)]
    charged = session._charge_conversion(tasks, cm, data)
    assert sorted(t.cost for t in charged) == pytest.approx([1.0, 1.0, 6.0])
    # once the entry is resident the same call charges nothing
    prepare_cached(data, "quantized_bins", {"max_bins": 32})
    uncharged = session._charge_conversion(tasks, cm, data)
    assert [t.cost for t in uncharged] == [1.0, 1.0, 1.0]


def test_fused_charge_survives_bucket_split():
    """The conversion charge rides on a MEMBER (charge_member), so
    split_at_buckets / restrict — which re-sum member costs — keep it."""
    from repro.core.fusion import FusedBatch

    tasks = tuple(TrainTask(task_id=i, estimator="gbdt",
                            params={"round": 4 if i < 2 else 64}, cost=1.0)
                  for i in range(4))
    unit = FusedBatch(tasks=tasks, signature=("gbdt", 64),
                      buckets=(0, 0, 1, 1), cost=4.0)
    charged = unit.charge_member(10.0)
    assert charged.cost == pytest.approx(14.0)
    pieces = charged.split_at_buckets()
    assert sum(p.cost for p in pieces) == pytest.approx(14.0)
    kept = charged.restrict({0, 1, 2, 3})
    assert kept.cost == pytest.approx(14.0)


def test_charge_conversion_respects_mesh_placements(data):
    """Mesh backend: a format counts as warm only when EVERY slice holds
    it; resident-everywhere groups are not re-charged (and a custom
    task_runner reports no placements => no charging at all)."""
    cm = CostModel()
    key = format_key("dense_rows")
    cm.observe_convert(key, 5.0, data.n_rows)
    cache = PreparedDataCache()
    pool = MeshSliceExecutorPool(slices=["s0", "s1"], prepared_cache=cache)
    spec = SearchSpec.from_dict({
        "spaces": [{"estimator": "logreg", "grid": {"c": [0.1]}}],
        "n_executors": 2})
    session = Session(spec, backend=pool)
    tasks = [TrainTask(task_id=i, estimator="logreg",
                       params={"c": 0.1, "steps": 5}, cost=1.0)
             for i in range(4)]
    charged = session._charge_conversion(tasks, cm, data)
    assert sorted(t.cost for t in charged) == pytest.approx([1, 1, 1, 6])
    # run the plan: both slices build their resident copy -> warm everywhere
    list(pool.submit(schedule(charged, 2, policy="lpt"), data))
    assert cache.counters()[1] == 2
    uncharged = session._charge_conversion(tasks, cm, data)
    assert [t.cost for t in uncharged] == [1.0] * 4


# --------------------------------------------------------------------------
# Acceptance: 64-config gbdt grid converts once per (fingerprint, max_bins).
# --------------------------------------------------------------------------

def test_session_64_config_grid_converts_once_per_variant(data):
    spec = SearchSpec.from_dict({
        "spaces": [{
            "estimator": "gbdt",
            "grid": {
                "eta": [0.1, 0.3],
                "lambda": [0.5, 1.0],
                "gamma": [0.0, 0.1],
                "min_child_weight": [1.0, 2.0],
                "round": [1, 2],
                "max_depth": [2],
                "max_bin": [16, 32],
            },
        }],
        "n_executors": 2,
        "policy": "lpt",
        "profiler": {"kind": "analytic"},
    })
    assert spec.n_grid_tasks == 64
    session = Session(spec)
    results = list(session.results(data))
    assert len(results) == 64 and all(r.ok for r in results)
    # EXACTLY one conversion per (dataset-fingerprint, max_bins) pair —
    # across 64 tasks on 2 racing executor threads
    assert session.stats.prepared_cache_misses == 2
    assert session.stats.prepared_cache_hits == 62
    assert session.stats.prepared_cache_hit_rate == pytest.approx(62 / 64)
    # the conversion seconds the search actually paid are surfaced (and
    # equal the sum over the two builder tasks)
    paid = [r.convert_seconds for r in results if r.convert_seconds > 0]
    assert len(paid) == 2
    assert session.stats.convert_seconds_total == pytest.approx(sum(paid))


def test_session_fused_and_sequential_rounds_share_cache(data):
    """A fused session and a sequential session over the same grid hit the
    SAME process-wide entries: the second run converts nothing."""
    base = {
        "spaces": [{"estimator": "gbdt",
                    "grid": {"eta": [0.1, 0.3, 0.9],
                             "round": [1, 2], "max_depth": [2],
                             "max_bin": [16]}}],
        "n_executors": 2,
        "profiler": {"kind": "analytic"},
    }
    fused = Session(SearchSpec.from_dict({**base, "fuse": True, "max_fuse": 3}))
    list(fused.results(data))
    assert fused.stats.prepared_cache_misses == 1
    seq = Session(SearchSpec.from_dict(base))
    results = list(seq.results(data))
    assert seq.stats.prepared_cache_misses == 0
    assert seq.stats.prepared_cache_hits == len(results)
    assert seq.stats.convert_seconds_total == 0.0


def test_wal_journals_convert_seconds(data, tmp_path):
    from repro.core import SearchWAL

    wal_path = str(tmp_path / "wal.jsonl")
    pool = LocalExecutorPool(1, wal=SearchWAL(wal_path),
                             prepared_cache=PreparedDataCache())
    tasks = [TrainTask(task_id=i, estimator="logreg",
                       params={"c": 0.1, "steps": 5}, cost=1.0)
             for i in range(2)]
    pool.run(schedule(tasks, 1, policy="lpt"), data)
    recs = SearchWAL(wal_path).completed()
    assert sorted(recs) == [0, 1]
    assert sum(1 for r in recs.values() if r.convert_seconds > 0) == 1
