"""Checkpoint/restart + data-pipeline determinism (fault-tolerance layer)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.synthetic import TokenStream


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    tree = {
        "step": jnp.int32(7),
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "nested": {"b": jnp.ones(5, jnp.bfloat16)}},
    }
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored = restore_checkpoint(str(tmp_path))
    assert step == 7
    tree_eq(tree, restored)


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, {"x": jnp.float32(s)})
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert kept == ["ckpt-3.npz", "ckpt-4.npz"]


def test_save_every_policy(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=5, keep=0, async_save=False)
    saved = [s for s in range(1, 21) if mgr.maybe_save(s, {"x": jnp.float32(s)})]
    assert saved == [5, 10, 15, 20]


def test_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=3, async_save=True)
    mgr.maybe_save(1, {"x": jnp.arange(1000.0)})
    mgr.wait()
    step, tree = restore_checkpoint(str(tmp_path))
    assert step == 1 and tree["x"].shape == (1000,)


def test_no_partial_checkpoint_on_disk(tmp_path):
    """Temp files never count as checkpoints (atomic-publish contract)."""
    # simulate a crashed writer: leave a temp file behind
    with open(tmp_path / ".tmp-ckpt-9.npz", "wb") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 2, {"x": jnp.float32(1)})
    assert latest_step(str(tmp_path)) == 2


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"))


def test_token_stream_restart_determinism():
    """batch(step) is a pure function of (seed, step) — the resume contract."""
    s1 = TokenStream(4, 16, 1000, seed=3)
    s2 = TokenStream(4, 16, 1000, seed=3)
    for step in (0, 5, 17):
        b1, b2 = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different seeds/steps differ
    assert not np.array_equal(s1.batch_at(0)["tokens"], s1.batch_at(1)["tokens"])
