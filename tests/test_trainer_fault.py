"""Trainer-level fault tolerance: crash-resume, transient retry, NaN skip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import make_lm_stream
from repro.launch.mesh import make_test_mesh
from repro.train import Trainer, make_optimizer


@pytest.fixture
def mesh():
    return make_test_mesh(data=1, model=1)


def _mk(mesh, tmp_path=None, **kw):
    cfg = configs.get_smoke_config("qwen2_1_5b")
    stream = make_lm_stream(mesh, batch=4, seq_len=32, vocab=cfg.vocab, seed=0)
    tr = Trainer(cfg, make_optimizer("adamw", lr=3e-3), mesh, stream,
                 ckpt_dir=str(tmp_path) if tmp_path else None,
                 ckpt_every=5, **kw)
    return tr, stream


def test_crash_resume_identical_to_uninterrupted(mesh, tmp_path):
    """Train 6 steps, 'crash', resume to 10 == training 10 straight
    (same data stream, same ckpt step → bitwise-equal losses)."""
    tr1, s1 = _mk(mesh, tmp_path / "a")
    tr1.run(6)                                # ckpt at step 5
    tr1b, s1b = _mk(mesh, tmp_path / "a")     # new process, same dir
    start = tr1b.init_or_restore()
    assert start == 5
    m1 = tr1b.run(10)
    s1.close(), s1b.close()

    tr2, s2 = _mk(mesh, tmp_path / "b")
    m2 = tr2.run(10)
    s2.close()
    resumed = {h["step"]: h["loss"] for h in m1.history}
    straight = {h["step"]: h["loss"] for h in m2.history}
    for step in range(5, 10):
        np.testing.assert_allclose(resumed[step], straight[step], rtol=1e-5), step


def test_transient_failure_retried(mesh):
    boom = {"left": 2}

    def failure_hook(step):
        if step == 3 and boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("injected transient device error")

    tr, s = _mk(mesh, None, failure_hook=failure_hook, max_retries=3)
    m = tr.run(6)
    s.close()
    assert m.retries == 2
    assert len(m.history) == 6                # all steps completed


def test_hard_failure_restores_checkpoint(mesh, tmp_path):
    calls = {"n": 0}

    def failure_hook(step):
        # step 7 fails persistently the first 4 times it is attempted
        if step == 7 and calls["n"] < 4:
            calls["n"] += 1
            raise RuntimeError("persistent fault")

    tr, s = _mk(mesh, tmp_path, failure_hook=failure_hook, max_retries=2)
    m = tr.run(9)
    s.close()
    assert m.restores >= 1                    # rolled back to ckpt-5
    assert m.history[-1]["step"] == 8         # and still finished


def test_nonfinite_step_dropped(mesh):
    """A poisoned batch (NaN loss) must not corrupt the params."""
    cfg = configs.get_smoke_config("qwen2_1_5b")
    stream = make_lm_stream(mesh, batch=4, seq_len=32, vocab=cfg.vocab, seed=0)
    tr = Trainer(cfg, make_optimizer("adamw", lr=1e30), mesh, stream)
    # lr=1e30 → immediate inf/NaN updates; the in-graph guard drops them
    m = tr.run(3)
    stream.close()
    leaves = jax.tree.leaves(tr.state["params"])
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
