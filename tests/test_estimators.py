"""Tabular estimator quality + property tests (the paper's 4 algorithms)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic stub, same surface
    from _hypothesis_stub import given, settings, st

import repro.tabular  # noqa: F401
from repro.core import DenseMatrix, auc, convert, get_estimator, estimator_names
from repro.data.synthetic import make_secom_like


def test_all_four_registered():
    assert set(estimator_names()) >= {"gbdt", "mlp", "forest", "logreg"}


@pytest.mark.parametrize("name,params,min_auc", [
    ("gbdt", {"round": 20, "max_depth": 5, "max_bin": 64}, 0.90),
    ("mlp", {"network": "32_32", "steps": 400}, 0.90),
    ("forest", {"n_estimators": 30, "max_depth": 8}, 0.84),
    ("logreg", {"c": 0.3}, 0.80),
])
def test_estimator_beats_chance_on_higgs(higgs_small, name, params, min_auc):
    train, valid = higgs_small
    est = get_estimator(name)
    model, secs = est.run(train, params)
    score = auc(valid.y, model.predict_proba(valid.x))
    assert score >= min_auc, f"{name} auc={score:.3f} < {min_auc}"
    assert secs > 0


def test_gbdt_on_imbalanced_secom_like():
    data = make_secom_like(n_rows=800, n_features=120, seed=3)
    train, valid = data.split((0.8, 0.2), seed=0)
    train, mu, sd = train.standardize()
    valid, _, _ = valid.standardize(mu, sd)
    est = get_estimator("gbdt")
    model, _ = est.run(train, {"round": 30, "max_depth": 4, "max_bin": 64})
    score = auc(valid.y, model.predict_proba(valid.x))
    assert score > 0.6                          # imbalanced + noisy: modest bar


def test_gbdt_more_rounds_fits_train_better(higgs_small):
    train, _ = higgs_small
    est = get_estimator("gbdt")
    m_small, _ = est.run(train, {"round": 3, "max_depth": 4})
    m_big, _ = est.run(train, {"round": 40, "max_depth": 4})
    auc_small = auc(train.y, m_small.predict_proba(train.x))
    auc_big = auc(train.y, m_big.predict_proba(train.x))
    assert auc_big > auc_small


def test_gbdt_predictions_are_probabilities(higgs_small):
    train, valid = higgs_small
    model, _ = get_estimator("gbdt").run(train, {"round": 5, "max_depth": 3})
    p = model.predict_proba(valid.x)
    assert p.shape == (valid.n_rows,)
    assert np.all((p >= 0) & (p <= 1))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_forest_prob_range_property(seed):
    """Forest output is a mean of leaf means of {0,1} labels → always [0,1]."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(120, 6)).astype(np.float32)
    y = (x[:, 0] * x[:, 1] > 0).astype(np.float32)
    d = DenseMatrix(x, y)
    model, _ = get_estimator("forest").run(d, {"n_estimators": 4, "max_depth": 4})
    p = model.predict_proba(x)
    assert np.all((p >= 0.0) & (p <= 1.0))


def test_quantized_bins_roundtrip_consistency(higgs_small):
    """bin > s  ⇔  x > edges[s] — the split-threshold identity GBDT's
    float-space predictor relies on."""
    train, _ = higgs_small
    q = convert(train, "quantized_bins")
    bins = np.asarray(q["bins"])
    edges = np.asarray(q["edges"])             # (F, B−1)
    x = train.x
    f = 3
    for s in (5, 100, 200):
        if s >= edges.shape[1]:
            continue
        lhs = bins[:, f] > s
        rhs = x[:, f] > edges[f, s]
        np.testing.assert_array_equal(lhs, rhs)


def test_mlp_cost_model_monotonic():
    est = get_estimator("mlp")
    small = est.estimate_cost({"network": "32", "steps": 100}, 1000, 28)
    big = est.estimate_cost({"network": "256_256", "steps": 100}, 1000, 28)
    assert big > small


# ---------------------------------------------------------------------------
# histogram-subtraction / fused-kernel bit-identity pins (DESIGN.md §3.8)
#
# ``subtract=False`` replays the pre-subtraction training path op for op, so
# these pins say: the models this PR trains are byte-identical to the models
# the repo trained before it — on the solo fit, the resumable-rung fit, and
# the vmap-fused batch fit, for both tree families.
# ---------------------------------------------------------------------------

def _gbdt_fit_inputs(higgs_small, max_bin=64):
    from repro.tabular.gbdt import GBDTEstimator

    train, _ = higgs_small
    est = get_estimator("gbdt")
    q = convert(train, "quantized_bins")
    factor, n_cbins = GBDTEstimator._coarsen(int(q["n_bins"]), max_bin)
    base = est._base_margin(q["y"])
    return est, q, factor, n_cbins, base


def _assert_trees_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_gbdt_fit_subtraction_bit_identity(higgs_small):
    from repro.tabular.gbdt import _fit_gbdt

    est, q, factor, n_cbins, base = _gbdt_fit_inputs(higgs_small)
    rounds, depth = 8, 4
    args = (q["bins"], q["y"], jnp.float32(base),
            jnp.int32(factor), jnp.int32(n_cbins),
            jnp.int32(rounds), jnp.int32(depth),
            jnp.float32(0.3), jnp.float32(1.0), jnp.float32(0.0),
            jnp.float32(1.0))
    kw = dict(n_bins=n_cbins, rounds=rounds, max_depth=depth)
    sub = _fit_gbdt(*args, subtract=True, **kw)
    direct = _fit_gbdt(*args, subtract=False, **kw)
    _assert_trees_equal(sub, direct)
    # the public estimator entry point routes through the same default path
    train, _ = higgs_small
    model, _ = est.run(train, {"round": rounds, "max_depth": depth,
                               "max_bin": 64})
    np.testing.assert_array_equal(model.feat, np.asarray(direct[0]))


def test_gbdt_fused_kernel_model_bit_identity(higgs_small):
    """ISSUE 9 acceptance pin: a model trained through the fused Pallas
    kernel (interpret mode on CPU) carries bit-identical feat/split/leaves
    to the XLA path — the split DECISIONS agree, and leaf sums are computed
    by the same scatter given identical routing."""
    from repro.tabular.gbdt import _fit_gbdt

    _, q, factor, n_cbins, base = _gbdt_fit_inputs(higgs_small)
    bins, y = q["bins"][:400], q["y"][:400]
    rounds, depth = 3, 3
    args = (bins, y, jnp.float32(base),
            jnp.int32(factor), jnp.int32(n_cbins),
            jnp.int32(rounds), jnp.int32(depth),
            jnp.float32(0.3), jnp.float32(1.0), jnp.float32(0.0),
            jnp.float32(1.0))
    kw = dict(n_bins=n_cbins, rounds=rounds, max_depth=depth)
    kernel = _fit_gbdt(*args, subtract=True, force="kernel", **kw)
    xla = _fit_gbdt(*args, subtract=False, **kw)
    _assert_trees_equal(kernel, xla)


def test_gbdt_resume_subtraction_bit_identity(higgs_small):
    from repro.tabular.gbdt import _resume_gbdt

    _, q, factor, n_cbins, base = _gbdt_fit_inputs(higgs_small)
    rounds, depth = 6, 4
    margin0 = jnp.full((q["bins"].shape[0],), base, jnp.float32)
    args = (q["bins"], q["y"], margin0,
            jnp.int32(factor), jnp.int32(n_cbins),
            jnp.int32(rounds), jnp.int32(depth),
            jnp.float32(0.3), jnp.float32(1.0), jnp.float32(0.0),
            jnp.float32(1.0), jnp.int32(0))
    kw = dict(n_bins=n_cbins, rounds=rounds, max_depth=depth)
    trees_s, margin_s = _resume_gbdt(*args, subtract=True, **kw)
    trees_d, margin_d = _resume_gbdt(*args, subtract=False, **kw)
    _assert_trees_equal(trees_s, trees_d)
    np.testing.assert_array_equal(np.asarray(margin_s), np.asarray(margin_d))


def test_gbdt_batched_fit_subtraction_bit_identity(higgs_small):
    """The vmap-fused plane (train_batched's compile-cache unit)."""
    from repro.tabular.gbdt import _build_batched_fit

    _, q, factor, n_cbins, base = _gbdt_fit_inputs(higgs_small)
    rounds, depth = 4, 4
    col = lambda v, dt: jnp.asarray(np.asarray(v, dt))  # noqa: E731
    args = (q["bins"], q["y"], jnp.float32(base),
            col([factor, factor], np.int32), col([n_cbins, 32], np.int32),
            col([rounds, 2], np.int32), col([depth, 2], np.int32),
            col([0.3, 0.1], np.float32), col([1.0, 2.0], np.float32),
            col([0.0, 0.5], np.float32), col([1.0, 3.0], np.float32))
    sub = _build_batched_fit(n_cbins, rounds, depth, subtract=True)(*args)
    direct = _build_batched_fit(n_cbins, rounds, depth, subtract=False)(*args)
    _assert_trees_equal(sub, direct)


def test_forest_fit_subtraction_bit_identity(higgs_small):
    from repro.tabular.forest import _fit_forest

    train, _ = higgs_small
    q = convert(train, "quantized_bins")
    bins = q["bins"] // 4                       # 256 → 64 levels
    key = jax.random.PRNGKey(11)
    kw = dict(n_bins=64, n_trees=5, max_depth=4, max_features=5)
    sub = _fit_forest(bins, q["y"], key, jnp.float32(1.0), jnp.int32(4),
                      subtract=True, **kw)
    direct = _fit_forest(bins, q["y"], key, jnp.float32(1.0), jnp.int32(4),
                         subtract=False, **kw)
    _assert_trees_equal(sub, direct)
