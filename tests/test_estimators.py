"""Tabular estimator quality + property tests (the paper's 4 algorithms)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic stub, same surface
    from _hypothesis_stub import given, settings, st

import repro.tabular  # noqa: F401
from repro.core import DenseMatrix, auc, convert, get_estimator, estimator_names
from repro.data.synthetic import make_secom_like


def test_all_four_registered():
    assert set(estimator_names()) >= {"gbdt", "mlp", "forest", "logreg"}


@pytest.mark.parametrize("name,params,min_auc", [
    ("gbdt", {"round": 20, "max_depth": 5, "max_bin": 64}, 0.90),
    ("mlp", {"network": "32_32", "steps": 400}, 0.90),
    ("forest", {"n_estimators": 30, "max_depth": 8}, 0.84),
    ("logreg", {"c": 0.3}, 0.80),
])
def test_estimator_beats_chance_on_higgs(higgs_small, name, params, min_auc):
    train, valid = higgs_small
    est = get_estimator(name)
    model, secs = est.run(train, params)
    score = auc(valid.y, model.predict_proba(valid.x))
    assert score >= min_auc, f"{name} auc={score:.3f} < {min_auc}"
    assert secs > 0


def test_gbdt_on_imbalanced_secom_like():
    data = make_secom_like(n_rows=800, n_features=120, seed=3)
    train, valid = data.split((0.8, 0.2), seed=0)
    train, mu, sd = train.standardize()
    valid, _, _ = valid.standardize(mu, sd)
    est = get_estimator("gbdt")
    model, _ = est.run(train, {"round": 30, "max_depth": 4, "max_bin": 64})
    score = auc(valid.y, model.predict_proba(valid.x))
    assert score > 0.6                          # imbalanced + noisy: modest bar


def test_gbdt_more_rounds_fits_train_better(higgs_small):
    train, _ = higgs_small
    est = get_estimator("gbdt")
    m_small, _ = est.run(train, {"round": 3, "max_depth": 4})
    m_big, _ = est.run(train, {"round": 40, "max_depth": 4})
    auc_small = auc(train.y, m_small.predict_proba(train.x))
    auc_big = auc(train.y, m_big.predict_proba(train.x))
    assert auc_big > auc_small


def test_gbdt_predictions_are_probabilities(higgs_small):
    train, valid = higgs_small
    model, _ = get_estimator("gbdt").run(train, {"round": 5, "max_depth": 3})
    p = model.predict_proba(valid.x)
    assert p.shape == (valid.n_rows,)
    assert np.all((p >= 0) & (p <= 1))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_forest_prob_range_property(seed):
    """Forest output is a mean of leaf means of {0,1} labels → always [0,1]."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(120, 6)).astype(np.float32)
    y = (x[:, 0] * x[:, 1] > 0).astype(np.float32)
    d = DenseMatrix(x, y)
    model, _ = get_estimator("forest").run(d, {"n_estimators": 4, "max_depth": 4})
    p = model.predict_proba(x)
    assert np.all((p >= 0.0) & (p <= 1.0))


def test_quantized_bins_roundtrip_consistency(higgs_small):
    """bin > s  ⇔  x > edges[s] — the split-threshold identity GBDT's
    float-space predictor relies on."""
    train, _ = higgs_small
    q = convert(train, "quantized_bins")
    bins = np.asarray(q["bins"])
    edges = np.asarray(q["edges"])             # (F, B−1)
    x = train.x
    f = 3
    for s in (5, 100, 200):
        if s >= edges.shape[1]:
            continue
        lhs = bins[:, f] > s
        rhs = x[:, f] > edges[f, s]
        np.testing.assert_array_equal(lhs, rhs)


def test_mlp_cost_model_monotonic():
    est = get_estimator("mlp")
    small = est.estimate_cost({"network": "32", "steps": 100}, 1000, 28)
    big = est.estimate_cost({"network": "256_256", "steps": 100}, 1000, 28)
    assert big > small
