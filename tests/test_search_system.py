"""End-to-end model-search behaviour (paper §III): driver → tuner →
profiler → scheduler → executors, plus the fault-tolerance contracts."""
import os
import threading

import numpy as np
import pytest

import repro.tabular  # noqa: F401 — registers estimators
from repro.core import (
    AnalyticProfiler,
    ExecutorFailure,
    GridBuilder,
    ModelSearcher,
    SamplingProfiler,
    SearchWAL,
    SuccessiveHalvingTuner,
    SurrogateTuner,
    attach_costs,
    available_formats,
    convert,
    enumerate_tasks,
)
from repro.core.data_format import DenseMatrix


def small_spaces():
    return [
        GridBuilder("logreg").add_grid("c", [0.05, 0.3]).add_grid("steps", [60]).build(),
        GridBuilder("mlp").add_grid("network", ["16_16"]).add_grid("steps", [60]).build(),
        GridBuilder("gbdt").add_grid("round", [5]).add_grid("max_depth", [3]).build(),
        GridBuilder("forest").add_grid("n_estimators", [5]).add_grid("max_depth", [4]).build(),
    ]


def test_grid_builder_cartesian():
    g = (GridBuilder("gbdt").add_grid("eta", [0.1, 0.3, 0.9])
         .add_grid("round", [30, 60, 90]).add_grid("max_bin", [32, 64, 128]).build())
    assert len(g) == 27                       # the paper's XGBoost grid
    tasks = enumerate_tasks([g])
    assert len({t.key() for t in tasks}) == 27


def test_search_end_to_end_lpt(higgs_small):
    train, valid = higgs_small
    s = ModelSearcher(n_executors=2).set_scheduler("lpt").set_profiler(
        SamplingProfiler(0.05)
    )
    for sp in small_spaces():
        s.add_space(sp)
    multi = s.model_search(train)
    assert len(multi) == 5                    # logreg:2 + mlp:1 + gbdt:1 + forest:1
    best = multi.best(valid, metric="auc")
    assert best.score > 0.7
    assert s.stats.profiling_seconds > 0
    assert s.stats.profiling_ratio < 0.9


def test_search_policies_same_results(higgs_small):
    """Scheduling policy affects time, never which models are produced."""
    train, valid = higgs_small
    scores = {}
    for policy in ("lpt", "random", "round_robin", "dynamic"):
        s = ModelSearcher(n_executors=3, seed=1).set_scheduler(policy)
        s.set_profiler(SamplingProfiler(0.05))
        for sp in small_spaces():
            s.add_space(sp)
        multi = s.model_search(train)
        ranked = multi.validate_all(valid, metric="auc")
        scores[policy] = {m.task.key(): round(m.score, 4) for m in ranked}
    base = scores["lpt"]
    for policy, sc in scores.items():
        assert sc == base, f"{policy} changed model outcomes"


def test_analytic_profiler_orders_like_sampling(higgs_small):
    train, _ = higgs_small
    spaces = [
        GridBuilder("gbdt").add_grid("round", [3, 60]).add_grid("max_depth", [3]).build(),
        GridBuilder("logreg").add_grid("c", [0.3]).build(),
    ]
    tasks = enumerate_tasks(spaces)
    rep = AnalyticProfiler().profile(tasks, train)
    costs = [rep.costs[t.task_id] for t in tasks]
    assert costs[1] > costs[0]                 # 60 rounds > 3 rounds
    # logreg under the heavyweight ensemble (the §3.8 subtraction discount
    # halves gbdt's histogram estimate, so the margin needs 60 rounds)
    assert costs[2] < costs[1]


def test_wal_restart_skips_completed(higgs_small, tmp_path):
    train, _ = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    s1 = ModelSearcher(n_executors=2).set_wal(wal_path).set_profiler(
        SamplingProfiler(0.05)
    )
    for sp in small_spaces():
        s1.add_space(sp)
    m1 = s1.model_search(train)
    assert os.path.exists(wal_path)
    # restart: everything already in the WAL → nothing re-runs
    s2 = ModelSearcher(n_executors=2).set_wal(wal_path).set_profiler(
        SamplingProfiler(0.05)
    )
    for sp in small_spaces():
        s2.add_space(sp)
    m2 = s2.model_search(train)
    assert len(m2) == 0
    wal = SearchWAL(wal_path)
    assert len(wal.completed()) == len(m1)


def test_executor_failure_recovery(higgs_small):
    """Kill executor 0 on its first task: others absorb its queue."""
    train, valid = higgs_small
    killed = []

    def failure_hook(eid, task):
        if eid == 0 and not killed:
            killed.append(task.task_id)
            raise ExecutorFailure(f"executor {eid} died")

    s = (ModelSearcher(n_executors=3)
         .set_profiler(SamplingProfiler(0.05))
         .set_pool_options(failure_hook=failure_hook))
    for sp in small_spaces():
        s.add_space(sp)
    multi = s.model_search(train)
    assert len(multi) == 5                     # every task still completed
    assert multi.best(valid).score > 0.6


def test_straggler_speculation(higgs_small):
    """A task stuck on a slow executor is duplicated; first result wins."""
    train, _ = higgs_small
    slow_once = threading.Event()

    def failure_hook(eid, task):
        # executor 0 sleeps a long time on its first task (a "straggler")
        if eid == 0 and not slow_once.is_set():
            slow_once.set()
            import time
            time.sleep(1.5)

    s = (ModelSearcher(n_executors=2)
         .set_scheduler("dynamic")
         .set_profiler(SamplingProfiler(0.05))
         .set_pool_options(failure_hook=failure_hook, speculation_factor=3.0))
    for sp in small_spaces():
        s.add_space(sp)
    multi = s.model_search(train)
    assert len(multi) == 5


def test_successive_halving_tuner(higgs_small):
    train, valid = higgs_small
    spaces = [
        GridBuilder("logreg").add_grid("c", [0.005, 0.05, 0.3, 0.9]).build(),
    ]
    tuner = SuccessiveHalvingTuner(spaces, budget_param="steps",
                                   base_budget=20, max_budget=100, eta=2)
    s = (ModelSearcher(n_executors=2).set_tuner(tuner)
         .set_profiler(SamplingProfiler(0.1)))
    multi = s.model_search(train, valid)
    # budgets 20/40/80/100 → rungs of 4, 2, 1, 1 configs = 8 evaluations
    assert len(multi) == 8


def test_surrogate_tuner_explores_then_exploits(higgs_small):
    train, valid = higgs_small
    spaces = [GridBuilder("logreg").add_grid(
        "c", [0.001, 0.01, 0.1, 0.3, 0.9, 2.0]).build()]
    tuner = SurrogateTuner(spaces, batch_size=2, rounds=3)
    s = (ModelSearcher(n_executors=2).set_tuner(tuner)
         .set_profiler(SamplingProfiler(0.1)))
    multi = s.model_search(train, valid)
    assert len(multi) == 6


def test_data_format_converters(higgs_small):
    train, _ = higgs_small
    assert set(available_formats()) >= {
        "dense_rows", "dense_cols", "quantized_bins", "sparse_csr"
    }
    rows = convert(train, "dense_rows")
    cols = convert(train, "dense_cols")
    np.testing.assert_allclose(np.asarray(rows["x"]).T, np.asarray(cols["xt"]),
                               rtol=1e-6)
    q = convert(train, "quantized_bins")
    assert int(q["bins"].max()) < int(q["n_bins"])
    csr = convert(train, "sparse_csr")
    assert int(csr["indptr"][-1]) == len(csr["values"])


def test_dense_matrix_validation():
    with pytest.raises(ValueError):
        DenseMatrix(np.zeros((4, 2)), np.zeros(3))
    with pytest.raises(ValueError):
        DenseMatrix(np.zeros(4), np.zeros(4))
    d = DenseMatrix(np.random.randn(100, 5), np.random.randint(0, 2, 100))
    sample = d.sample(0.25)
    assert sample.n_rows == 25
    parts = d.split((0.6, 0.2, 0.2))
    assert sum(p.n_rows for p in parts) == 100
