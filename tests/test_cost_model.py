"""Profile-feedback subsystem: CostModel learning/persistence, drift-triggered
replanning, the profiler-contract fix, and the device-free recovery sim that
the CI bench gate reproduces (DESIGN.md §3.1)."""
import json
import math
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

import repro.tabular  # noqa: F401 — registers estimators
from repro.core import (
    AnalyticProfiler,
    CostModel,
    Estimator,
    GridBuilder,
    LocalExecutorPool,
    MeshSliceExecutorPool,
    ProfileReport,
    SamplingProfiler,
    SearchSpec,
    Session,
    TrainTask,
    TrainedModel,
    get_estimator,
    observed_drift,
    param_bucket,
    plan_makespan_estimate,
    register_estimator,
    replan,
    restrict,
    schedule,
    simulate_makespan,
    simulate_replan,
    unregister_estimator,
)


def _task(tid=0, est="gbdt", cost=None, **params):
    return TrainTask(task_id=tid, estimator=est, params=params, cost=cost)


# ---------------------------------------------------------------------------
# param_bucket + observed_drift
# ---------------------------------------------------------------------------

def test_param_bucket_groups_magnitudes():
    # same power-of-two magnitude -> same bucket; different magnitude -> not
    assert param_bucket({"round": 400}) == param_bucket({"round": 512})
    assert param_bucket({"round": 30}) != param_bucket({"round": 300})
    assert param_bucket({"lr": 0.003}) != param_bucket({"lr": 0.03})
    # strings/bools verbatim, key order irrelevant
    assert param_bucket({"a": 1, "net": "64_64"}) == param_bucket({"net": "64_64", "a": 1})
    assert param_bucket({"net": "64_64"}) != param_bucket({"net": "128_64"})


def test_observed_drift():
    assert observed_drift([]) == 0.0
    assert observed_drift([(2.0, 2.0), (5.0, 5.0)]) == 0.0
    assert observed_drift([(1.0, 2.0)]) == pytest.approx(math.log(2))
    # symmetric: over- and under-estimates both count
    assert observed_drift([(2.0, 1.0)]) == pytest.approx(math.log(2))
    # failed tasks report 0 observed seconds and must not register
    assert observed_drift([(1.0, 0.0), (0.0, 1.0)]) == 0.0


# ---------------------------------------------------------------------------
# CostModel: learning, fallbacks, persistence
# ---------------------------------------------------------------------------

def test_cost_model_learns_bucket_then_family():
    cm = CostModel()
    assert cm.predict(_task(est="gbdt", round=60), 1000) is None
    cm.observe(_task(est="gbdt", round=60), seconds=2.0, n_rows=1000)
    # exact bucket
    assert cm.predict(_task(tid=9, est="gbdt", round=60), 1000) == pytest.approx(2.0)
    # same family, unseen bucket -> pooled family stats
    assert cm.predict(_task(tid=9, est="gbdt", round=5000), 1000) == pytest.approx(2.0)
    # other family -> nothing
    assert cm.predict(_task(tid=9, est="mlp", steps=60), 1000) is None
    # junk observations are ignored
    cm.observe(_task(est="gbdt", round=60), seconds=0.0, n_rows=1000)
    cm.observe(_task(est="gbdt", round=60), seconds=1.0, n_rows=0)
    assert cm.n_observed == 1


def test_cost_model_scaling_law_from_observations():
    cm = CostModel()
    # quadratic-ish growth observed at two sizes -> learned exponent ~2
    cm.observe(_task(est="mlp", steps=64), seconds=1.0, n_rows=1000)
    cm.observe(_task(tid=1, est="mlp", steps=64), seconds=4.0, n_rows=2000)
    pred = cm.predict(_task(tid=9, est="mlp", steps=64), 4000)
    assert pred == pytest.approx(16.0, rel=0.05)


def test_cost_model_ratio_prior_corrects_unseen_bucket():
    cm = CostModel()
    # observed task ran 4x over its estimate (cost=0.5 -> 2.0s)
    cm.observe(_task(est="gbdt", round=60, cost=0.5), seconds=2.0, n_rows=1000)
    # unseen bucket, but the task carries its own (equally wrong) estimate:
    # estimate() scales it by the family's observed/estimated ratio
    t = _task(tid=9, est="gbdt", round=7, cost=1.0)
    assert cm.estimate(t, 1000) == pytest.approx(4.0)
    # predict() (pure size law) falls back to the family mean instead
    assert cm.predict(t, 1000) == pytest.approx(2.0)


def test_cost_model_json_roundtrip(tmp_path):
    path = str(tmp_path / "cm.json")
    cm = CostModel(path)
    cm.observe(_task(est="gbdt", round=60, cost=1.0), seconds=2.0, n_rows=1000)
    cm.observe(_task(tid=1, est="mlp", steps=300), seconds=0.5, n_rows=1000)
    cm.save()
    loaded = CostModel.open(path)
    assert loaded.n_observed == 2
    for t in (_task(tid=9, est="gbdt", round=60), _task(tid=9, est="mlp", steps=300)):
        assert loaded.predict(t, 2000) == pytest.approx(cm.predict(t, 2000))
    # ratio prior survives the roundtrip too
    t = _task(tid=9, est="gbdt", round=9, cost=3.0)
    assert loaded.estimate(t, 1000) == pytest.approx(cm.estimate(t, 1000))
    # the file is plain JSON (the documented persistence format)
    with open(path) as f:
        payload = json.load(f)
    assert payload["version"] == 1 and "gbdt" in payload["families"]


def test_cost_model_open_missing_path_is_fresh(tmp_path):
    cm = CostModel.open(str(tmp_path / "nope.json"))
    assert cm.n_observed == 0
    assert cm.path is not None          # will save there later


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=1e-3, max_value=1e3), min_size=1, max_size=6),
       st.lists(st.integers(min_value=10, max_value=10**6), min_size=1, max_size=6))
def test_cost_model_predictions_monotone_in_data_size(secs, sizes):
    """Property (ISSUE satellite): more rows never predicts less time."""
    cm = CostModel()
    for i, (s, n) in enumerate(zip(secs, sizes)):
        cm.observe(_task(tid=i, est="fam", units=4), seconds=s, n_rows=n)
    probe = _task(tid=99, est="fam", units=4, cost=1.0)
    grid = [10, 100, 1_000, 10_000, 100_000, 1_000_000]
    preds = [cm.predict(probe, n) for n in grid]
    ests = [cm.estimate(probe, n) for n in grid]
    assert all(p is not None for p in preds)
    for seq in (preds, ests):
        for a, b in zip(seq, seq[1:]):
            assert a <= b * (1 + 1e-9)


# ---------------------------------------------------------------------------
# CostModel as the third profiler source
# ---------------------------------------------------------------------------

class _Flat(TrainedModel):
    def predict_proba(self, x):
        import numpy as np
        return np.full((x.shape[0],), 0.5, dtype=np.float32)


class _Counting(Estimator):
    name = "counting2"
    data_format = "dense_rows"
    trained: list = []

    def train(self, data, params):
        type(self).trained.append(dict(params))
        return _Flat()


@pytest.fixture
def counting2():
    _Counting.trained = []
    register_estimator(_Counting)
    yield _Counting
    unregister_estimator("counting2")


def test_cost_model_profile_beats_sampling_after_warmup(higgs_small, counting2):
    train, _ = higgs_small
    tasks = [_task(tid=i, est="counting2", i=i) for i in range(4)]
    cm = CostModel(fallback=SamplingProfiler(0.5))
    # cold: the fallback must actually train (the paper's sampled profile)
    report = cm.profile(tasks, train)
    assert set(report.costs) == {0, 1, 2, 3}
    cold_trained = len(counting2.trained)
    assert cold_trained > 0
    # warm up the model, then profile again: zero training, instant answers
    for t in tasks:
        cm.observe(t, seconds=0.05, n_rows=train.n_rows)
    report2 = cm.profile(tasks, train)
    assert set(report2.costs) == {0, 1, 2, 3}
    assert len(counting2.trained) == cold_trained     # fallback never invoked
    assert report2.profiling_seconds < 0.05           # vs a training run
    assert report2.sampling_rate is None


def test_spec_builds_cost_model_profiler(tmp_path):
    sp = GridBuilder("logreg").add_grid("c", [0.1]).build()
    spec = SearchSpec.from_dict({
        "spaces": [{"estimator": "logreg", "grid": {"c": [0.1]}}],
        "profiler": {"kind": "cost_model",
                     "fallback": {"kind": "sampling", "sampling_rate": 0.11}},
        "cost_model_path": str(tmp_path / "cm.json"),
        "replan_threshold": 0.5,
    })
    prof = spec.build_profiler()
    assert isinstance(prof, CostModel)
    assert prof.path == str(tmp_path / "cm.json")
    assert isinstance(prof.fallback, SamplingProfiler)
    assert prof.fallback.sampling_rate == 0.11
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], replan_threshold=0.0)
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], replan_threshold=-1)


# ---------------------------------------------------------------------------
# ProfileReport contract fix (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_ratio_of_contract_and_total_variant():
    report = ProfileReport(costs={}, profiling_seconds=2.0, sampling_rate=0.03)
    # ratio_of takes time EXCLUDING profiling and adds it itself
    assert report.ratio_of(8.0) == pytest.approx(0.2)
    # ratio_of_total takes a total that already INCLUDES profiling
    assert report.ratio_of_total(10.0) == pytest.approx(0.2)
    # the old double-count bug: passing the total to ratio_of understates
    assert report.ratio_of(10.0) < report.ratio_of_total(10.0)
    # clamping + degenerate inputs
    assert report.ratio_of_total(1.0) == 1.0
    assert report.ratio_of_total(0.0) == 0.0
    assert report.ratio_of(0.0) == 1.0


# ---------------------------------------------------------------------------
# Scheduler: replan / restrict / simulate_replan
# ---------------------------------------------------------------------------

def test_restrict_keeps_placement_and_new_costs():
    tasks = [_task(tid=i, est="a", i=i, cost=float(i + 1)) for i in range(6)]
    a = schedule(tasks, 2, policy="lpt")
    remaining = [t.with_cost(10.0) for t in tasks if t.task_id % 2 == 0]
    r = restrict(a, remaining)
    assert sorted(t.task_id for t in r.all_tasks()) == [0, 2, 4]
    assert all(t.cost == 10.0 for t in r.all_tasks())
    assert r.policy == "lpt"


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=0.01, max_value=100.0), min_size=1, max_size=16),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=15),
       st.sampled_from(["lpt", "dynamic", "lpt_dynamic"]))
def test_replan_never_increases_estimated_makespan(costs, m, n_done, policy):
    """Property (ISSUE satellite): replan returns the better of {fresh
    rebalance, current residual}, so the estimate can only improve."""
    tasks = [_task(tid=i, est="fam", i=i, cost=c) for i, c in enumerate(costs)]
    assignment = schedule(tasks, m, policy=policy)
    remaining = tasks[min(n_done, len(tasks)):]
    if not remaining:
        return
    # re-estimation moves costs around before the replan, as in the Session
    remaining = [t.with_cost(t.cost * (1 + (t.task_id % 5))) for t in remaining]
    residual = restrict(assignment, remaining)
    out = replan(remaining, m, current=residual, policy=policy)
    assert plan_makespan_estimate(out) <= plan_makespan_estimate(residual) * (1 + 1e-9)
    assert sorted(t.task_id for t in out.all_tasks()) == \
        sorted(t.task_id for t in remaining)


def _mis_estimated(n=40, m=4, factor=4.0):
    tasks, true = [], {}
    for i in range(n):
        fam = ("slow", "fast")[i % 2]
        true_cost = 4.0 + (i % 7) if fam == "slow" else 1.0
        est = true_cost / factor if fam == "slow" else true_cost
        tasks.append(_task(tid=i, est=fam, i=i // 2, cost=est))
        true[i] = true_cost
    return tasks, true, m


def test_simulate_replan_matches_static_when_threshold_never_trips():
    tasks, true, m = _mis_estimated()
    static = simulate_makespan(schedule(tasks, m, policy="lpt"), true)
    out = simulate_replan(tasks, m, true, threshold=1e9)
    assert out["replans"] == 0
    assert out["makespan"] == pytest.approx(static)
    assert out["observed"] == len(tasks)


def test_simulate_replan_recovers_makespan_gap():
    """Mirror of the CI-gated benchmark acceptance: feedback + replan claws
    back >= 25% of the static->oracle gap on a 4x mis-estimated task set."""
    tasks, true, m = _mis_estimated()
    static = simulate_makespan(schedule(tasks, m, policy="lpt"), true)
    oracle = simulate_makespan(
        schedule([t.with_cost(true[t.task_id]) for t in tasks], m, policy="lpt"), true)
    out = simulate_replan(tasks, m, true, threshold=0.25)
    assert out["replans"] >= 1
    assert static > oracle                      # the mis-estimate really hurts
    recovery = (static - out["makespan"]) / (static - oracle)
    assert recovery >= 0.25, f"recovered only {recovery:.1%}"
    # sanity: never better than the oracle's lower bound family
    assert out["makespan"] >= max(true.values()) - 1e-9


# ---------------------------------------------------------------------------
# Executor pools: on_result hook + straggler drain
# ---------------------------------------------------------------------------

class _Sleepy(Estimator):
    name = "sleepy"
    data_format = "dense_rows"

    def train(self, data, params):
        time.sleep(params["ms"] / 1000.0)
        return _Flat()


@pytest.fixture
def sleepy():
    register_estimator(_Sleepy)
    yield _Sleepy
    unregister_estimator("sleepy")


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_pools_invoke_on_result_hook(higgs_small, kind, counting2):
    train, _ = higgs_small
    seen = []
    if kind == "local":
        pool = LocalExecutorPool(2, on_result=seen.append)
    else:
        pool = MeshSliceExecutorPool(
            task_runner=lambda task, sl, data:
                get_estimator(task.estimator).run(data, task.params),
            slices=["s0", "s1"], on_result=seen.append)
    tasks = [_task(tid=i, est="counting2", i=i) for i in range(5)]
    results = list(pool.submit(schedule(tasks, 2, policy="round_robin"), train))
    assert sorted(r.task.task_id for r in seen) == sorted(r.task.task_id for r in results)


def test_pool_observer_exceptions_are_swallowed(higgs_small, counting2):
    train, _ = higgs_small

    def bad_observer(res):
        raise RuntimeError("broken observer")

    pool = LocalExecutorPool(2, on_result=bad_observer)
    tasks = [_task(tid=i, est="counting2", i=i) for i in range(4)]
    results = list(pool.submit(schedule(tasks, 2, policy="lpt"), train))
    assert len(results) == 4 and all(r.ok for r in results)


def test_local_pool_straggler_drain_loses_nothing(higgs_small, sleepy):
    train, _ = higgs_small
    pool = LocalExecutorPool(1)
    tasks = [_task(tid=i, est="sleepy", ms=30, cost=0.03) for i in range(3)]
    stream = pool.submit(schedule(tasks, 1, policy="lpt"), train)
    first = next(stream)
    stream.close()                      # cancel with work possibly in flight
    stragglers = pool.drain_stragglers()
    seen = {first.task.task_id} | {r.task.task_id for r in stragglers}
    # every journalled completion was surfaced through one of the two paths
    assert set(pool.wal.completed()) == seen
    assert pool.drain_stragglers() == []          # buffer clears on read


# ---------------------------------------------------------------------------
# Session integration: feedback loop end to end
# ---------------------------------------------------------------------------

def _sleepy_spec(tmp_path, *, est_ms, real_ms, n=6, **kw):
    """Analytic profile says est_ms; reality sleeps real_ms."""
    spaces = [GridBuilder("sleepy").add_grid("ms", [real_ms])
              .add_grid("i", list(range(n))).build()]
    return SearchSpec(
        spaces=spaces, n_executors=2, policy="lpt",
        profiler=AnalyticProfiler(cost_fn=lambda t, r, f: est_ms / 1000.0),
        **kw)


def test_session_replans_on_drift_and_completes_everything(tmp_path, higgs_small, sleepy):
    train, _ = higgs_small
    spec = _sleepy_spec(tmp_path, est_ms=10, real_ms=60,  # 6x under-estimated
                        replan_threshold=0.5,
                        cost_model_path=str(tmp_path / "cm.json"))
    session = Session(spec)
    out = list(session.results(train))
    assert session.stats.n_replans >= 1
    # the replan loop surfaced every task exactly once — nothing lost, no dupes
    assert sorted(r.task.task_id for r in out) == list(range(6))
    assert all(r.ok for r in out)
    # the model persisted next to the WAL path we chose and is warm
    warm = CostModel.open(str(tmp_path / "cm.json"))
    assert warm.n_observed >= 2
    probe = _task(tid=99, est="sleepy", ms=60, i=0)
    assert warm.predict(probe, train.n_rows) == pytest.approx(0.06, rel=0.5)


def test_session_cost_model_warm_start_skips_profiler(tmp_path, higgs_small, sleepy):
    train, _ = higgs_small
    path = str(tmp_path / "cm.json")
    cold = Session(_sleepy_spec(tmp_path, est_ms=20, real_ms=20, n=4,
                                cost_model_path=path))
    cold.search(train)
    assert cold.stats.n_profiled == 4 and cold.stats.n_model_estimates == 0
    # a LATER session over the same families starts warm: zero profiling
    warm = Session(_sleepy_spec(tmp_path, est_ms=20, real_ms=20, n=4,
                                cost_model_path=path))
    warm.search(train)
    assert warm.stats.n_model_estimates == 4
    assert warm.stats.n_profiled == 0
    assert warm.stats.profiling_seconds == 0.0


def test_session_default_cost_model_path_sits_next_to_wal(tmp_path, higgs_small, sleepy):
    train, _ = higgs_small
    wal = str(tmp_path / "search.wal")
    spec = _sleepy_spec(tmp_path, est_ms=20, real_ms=20, n=3,
                        wal_path=wal, replan_threshold=5.0)
    Session(spec).search(train)
    warm = CostModel.open(wal + ".cost.json")
    assert warm.n_observed == 3         # persisted without an explicit path


def test_declared_cost_model_profiler_persists_next_to_wal(tmp_path, higgs_small, sleepy):
    """A spec-declared {"kind": "cost_model"} profiler with no explicit path
    must still inherit the <wal>.cost.json default — and a later session
    declaring the same profiler must warm-load what it persisted."""
    train, _ = higgs_small
    wal = str(tmp_path / "w.jsonl")
    spaces = [GridBuilder("sleepy").add_grid("ms", [10])
              .add_grid("i", [0, 1, 2]).build()]

    def spec(wal_path):
        return SearchSpec(spaces=spaces, n_executors=1, policy="lpt",
                          profiler={"kind": "cost_model",
                                    "fallback": {"kind": "sampling",
                                                 "sampling_rate": 0.5}},
                          wal_path=wal_path, replan_threshold=5.0)

    s1 = Session(spec(wal))
    s1.search(train)
    assert s1.cost_model.path == wal + ".cost.json"
    assert CostModel.open(wal + ".cost.json").n_observed == 3

    s2 = Session(spec(str(tmp_path / "w2.jsonl"))
                 .replace(cost_model_path=wal + ".cost.json"))
    s2.search(train)
    assert s2.stats.n_model_estimates == 3      # warm-loaded, zero profiling
    assert s2.stats.n_profiled == 0


def test_reused_backend_replaces_stale_session_observer(tmp_path, higgs_small, counting2):
    """Two sessions sharing one pool: the second REPLACES the first's
    observer (no unbounded chain, no cross-feeding the dead session's model)."""
    train, _ = higgs_small
    user_hook_calls = []
    pool = LocalExecutorPool(1, on_result=user_hook_calls.append)
    spaces = [GridBuilder("counting2").add_grid("i", [0, 1]).build()]

    def spec(name):
        return SearchSpec(spaces=spaces, n_executors=1,
                          profiler=SamplingProfiler(0.5),
                          cost_model_path=str(tmp_path / name))

    s1 = Session(spec("cm1.json"), backend=pool)
    s1.search(train)
    n1 = s1.cost_model.n_observed
    assert n1 == 2
    from repro.core import SearchWAL
    pool.wal = SearchWAL(None)      # fresh journal: same task ids run again
    s2 = Session(spec("cm2.json"), backend=pool)
    s2.search(train)
    # session 1's model stopped growing; session 2's observed its own run
    assert s1.cost_model.n_observed == n1
    assert s2.cost_model.n_observed == 2
    # the chain is observer -> original user hook, depth 1, both runs seen
    assert getattr(pool.on_result, "_session_observer", False)
    assert not getattr(pool.on_result._chained_prev, "_session_observer", False)
    assert len(user_hook_calls) == 4


def test_compare_to_baseline_partial_run_skips_missing_keys():
    from benchmarks.run import compare_to_baseline

    baseline = {"a.makespan": 10.0, "b.makespan": 10.0, "c.other": 1.0}
    produced = {"a.makespan": 11.0}
    # partial (--only) run: missing gated rows are fine, present ones gate
    assert compare_to_baseline(produced, baseline, 0.2, full_run=False) == []
    assert compare_to_baseline({"a.makespan": 13.0}, baseline, 0.2,
                               full_run=False) != []
    # full run: a vanished gated row is itself a failure
    problems = compare_to_baseline(produced, baseline, 0.2, full_run=True)
    assert any("b.makespan" in p for p in problems)


def test_session_without_feedback_has_no_cost_model(higgs_small, counting2):
    train, _ = higgs_small
    spaces = [GridBuilder("counting2").add_grid("i", [0, 1]).build()]
    session = Session(SearchSpec(spaces=spaces, n_executors=1,
                                 profiler=SamplingProfiler(0.5)))
    session.search(train)
    assert session.cost_model is None
    assert session.stats.n_replans == 0
