"""Fused validation plane (DESIGN.md §3.4): jitted predictor parity,
executor-side scoring in both pools, scored streaming without driver-side
prediction, the CostModel eval law, and the memoized MultiModel."""
import numpy as np
import pytest

import repro.tabular  # noqa: F401  (registers the estimators)
from repro.core import (
    CostModel,
    DenseMatrix,
    GridBuilder,
    LocalExecutorPool,
    MeshSliceExecutorPool,
    MultiModel,
    SearchSpec,
    Session,
    TaskResult,
    TrainTask,
    charge_units,
    get_estimator,
    schedule,
    stable_sigmoid,
)
from repro.core.evaluation import EvalPlan, evaluate_models, predict_compile_cache
from repro.core.fault import WALRecord
from repro.core.fusion import FusedBatch
from repro.core.results import auc
from repro.tabular.forest import ForestModel
from repro.tabular.gbdt import GBDTModel
from repro.tabular.logreg import LogRegModel
from repro.tabular.mlp import MLPModel


@pytest.fixture(scope="module")
def small_data():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(400, 8)).astype(np.float32)
    y = (x[:, 0] + 0.3 * rng.normal(size=400) > 0).astype(np.float32)
    data = DenseMatrix(x, y)
    train, valid = data.split((0.75, 0.25), seed=0)
    return train, valid


# ---------------------------------------------------------------------------
# stable sigmoid (satellite: overflow fix)
# ---------------------------------------------------------------------------

class TestStableSigmoid:
    def test_extreme_margins_no_overflow(self):
        z = np.array([-1e4, -1000.0, -100.0, 0.0, 100.0, 1000.0, 1e4])
        with np.errstate(over="raise", invalid="raise"):
            p = stable_sigmoid(z)
        assert np.all((p >= 0.0) & (p <= 1.0))
        assert p[3] == 0.5
        assert p[0] == 0.0 and p[-1] == 1.0

    def test_matches_naive_in_safe_range(self):
        z = np.linspace(-30, 30, 101)
        naive = 1.0 / (1.0 + np.exp(-z))
        np.testing.assert_allclose(stable_sigmoid(z), naive, rtol=1e-12)

    def test_keeps_tiny_tail_precision(self):
        # naive float64 at z=-745 overflows exp and rounds to exactly 0 via
        # inf; the stable form returns the representable subnormal tail
        assert stable_sigmoid(np.array([-700.0]))[0] > 0.0

    def test_model_predict_proba_extreme_margins(self):
        # a gbdt model whose leaves pile up to huge |margin| must not warn
        feat = np.zeros((1, 1), np.int32)
        thresh = np.zeros((1, 1), np.float32)
        leaves = np.array([[-2000.0, 2000.0]], np.float32)
        m = GBDTModel(feat, thresh, leaves, base=0.0, max_depth=1)
        x = np.array([[-1.0], [1.0]], np.float32)
        with np.errstate(over="raise", invalid="raise"):
            p = m.predict_proba(x)
        assert p[0] == 0.0 and p[1] == 1.0


# ---------------------------------------------------------------------------
# jitted predictor parity (satellite: bit-level / ~1e-6 across families)
# ---------------------------------------------------------------------------

class TestJittedParity:
    def test_gbdt_solo_bitwise(self, small_data):
        train, valid = small_data
        est = get_estimator("gbdt")
        m, _ = est.run(train, {"round": 5, "max_depth": 3, "max_bin": 32})
        np.testing.assert_array_equal(m.predict_margin(valid.x),
                                      m.predict_margin_jax(valid.x))
        np.testing.assert_array_equal(m.predict_proba(valid.x),
                                      m.predict_proba_jax(valid.x))

    def test_gbdt_fused_depth_padded_bitwise(self, small_data):
        # heterogeneous rounds AND depths: train_batched pads depth with
        # sentinel splits; the batched predictor must route identically
        train, valid = small_data
        est = get_estimator("gbdt")
        configs = [{"round": r, "max_depth": d, "max_bin": 32, "eta": e}
                   for r, d, e in [(3, 2, 0.1), (5, 3, 0.3), (7, 3, 0.5),
                                   (4, 2, 0.9)]]
        models, _ = est.run_batched(train, configs)
        batched = GBDTModel.predict_margin_batched(models, valid.x)
        for i, m in enumerate(models):
            np.testing.assert_array_equal(m.predict_margin(valid.x), batched[i])

    def test_gbdt_mixed_depth_stack(self, small_data):
        # predict_*_batched groups by depth, so even a stack fused units
        # never produce (solo models of different depths) scores correctly
        train, valid = small_data
        est = get_estimator("gbdt")
        m2, _ = est.run(train, {"round": 3, "max_depth": 2, "max_bin": 32})
        m4, _ = est.run(train, {"round": 4, "max_depth": 4, "max_bin": 32})
        batched = GBDTModel.predict_proba_batched([m2, m4, m2], valid.x)
        np.testing.assert_array_equal(batched[0], m2.predict_proba(valid.x))
        np.testing.assert_array_equal(batched[1], m4.predict_proba(valid.x))
        np.testing.assert_array_equal(batched[2], batched[0])

    def test_forest_solo_and_batched_bitwise(self, small_data):
        train, valid = small_data
        est = get_estimator("forest")
        solo, _ = est.run(train, {"n_estimators": 5, "max_depth": 3})
        np.testing.assert_array_equal(solo.predict_proba(valid.x),
                                      solo.predict_proba_jax(valid.x))
        models, _ = est.run_batched(train, [
            {"n_estimators": n, "max_depth": 3, "seed": s}
            for n, s in [(3, 0), (5, 1), (4, 2)]])
        batched = ForestModel.predict_proba_batched(models, valid.x)
        for i, m in enumerate(models):
            np.testing.assert_array_equal(m.predict_proba(valid.x), batched[i])

    def test_logreg_parity(self, small_data):
        train, valid = small_data
        est = get_estimator("logreg")
        m, _ = est.run(train, {"steps": 50})
        np.testing.assert_allclose(m.predict_proba(valid.x),
                                   m.predict_proba_jax(valid.x), atol=1e-6)
        models, _ = est.run_batched(train, [{"steps": 50, "c": c}
                                            for c in (0.1, 0.5, 1.0)])
        batched = LogRegModel.predict_proba_batched(models, valid.x)
        for i, m in enumerate(models):
            np.testing.assert_allclose(m.predict_proba(valid.x), batched[i],
                                       atol=1e-6)

    def test_mlp_parity(self, small_data):
        train, valid = small_data
        est = get_estimator("mlp")
        m, _ = est.run(train, {"steps": 30, "network": "16_16"})
        np.testing.assert_allclose(m.predict_proba(valid.x),
                                   m.predict_proba_jax(valid.x), atol=1e-6)
        models, _ = est.run_batched(train, [
            {"steps": 30, "network": "16_16", "seed": s} for s in (0, 1, 2)])
        batched = MLPModel.predict_proba_batched(models, valid.x)
        for i, m in enumerate(models):
            np.testing.assert_allclose(m.predict_proba(valid.x), batched[i],
                                       atol=1e-6)

    def test_predict_compile_cache_reuses_programs(self, small_data):
        train, valid = small_data
        est = get_estimator("gbdt")
        m, _ = est.run(train, {"round": 6, "max_depth": 3, "max_bin": 32})
        cache = predict_compile_cache()
        m.predict_proba_jax(valid.x)
        hits0, misses0 = cache.counters()
        m.predict_proba_jax(valid.x)          # same (depth, pad, B, shape)
        hits1, misses1 = cache.counters()
        assert hits1 == hits0 + 1 and misses1 == misses0


# ---------------------------------------------------------------------------
# executor-side scoring (tentpole: both pools)
# ---------------------------------------------------------------------------

def _tasks(estimator, grids):
    return [TrainTask(task_id=i, estimator=estimator, params=p, cost=1.0)
            for i, p in enumerate(grids)]


class TestExecutorScoring:
    def test_local_pool_scores_match_driver(self, small_data):
        train, valid = small_data
        tasks = _tasks("gbdt", [{"round": 3, "max_depth": 2, "max_bin": 32,
                                 "eta": e} for e in (0.1, 0.3, 0.9)])
        pool = LocalExecutorPool(2)
        results = pool.run(schedule(tasks, 2), train, EvalPlan(valid, "auc"))
        assert len(results) == 3
        for r in results:
            assert r.ok and r.score is not None and r.eval_seconds > 0
            expected = auc(valid.y, r.model.predict_proba(valid.x))
            assert abs(r.score - expected) < 1e-6

    def test_local_pool_wal_carries_score(self, small_data):
        train, valid = small_data
        tasks = _tasks("logreg", [{"c": 0.1, "steps": 20}])
        pool = LocalExecutorPool(1)
        [res] = pool.run(schedule(tasks, 1), train, EvalPlan(valid, "auc"))
        rec = pool.wal.completed()[tasks[0].task_id]
        assert rec.score == res.score
        assert rec.eval_seconds == res.eval_seconds > 0

    def test_no_validate_means_no_score(self, small_data):
        train, _ = small_data
        tasks = _tasks("logreg", [{"c": 0.1, "steps": 20}])
        [res] = LocalExecutorPool(1).run(schedule(tasks, 1), train)
        assert res.score is None and res.eval_seconds == 0.0

    def test_fused_unit_scores_whole_batch(self, small_data):
        train, valid = small_data
        spec = SearchSpec(
            spaces=[GridBuilder("gbdt").add_grid("eta", [0.1, 0.3, 0.5, 0.9])
                    .add_grid("round", [3, 5]).build()],
            n_executors=2, fuse=True, max_fuse=4)
        session = Session(spec)
        results = list(session.results(train, valid))
        assert all(r.ok and r.score is not None for r in results)
        fused = [r for r in results if r.batch_size > 1]
        assert fused, "expected fused batches in this grid"
        assert all(r.eval_seconds > 0 for r in fused)

    def test_mesh_pool_scores_per_slice(self, small_data):
        train, valid = small_data
        pool = MeshSliceExecutorPool(slices=["s0", "s1"])
        tasks = _tasks("logreg", [{"c": c, "steps": 20}
                                  for c in (0.1, 0.3, 1.0, 3.0)])
        results = pool.run(schedule(tasks, 2, policy="round_robin"), train,
                           EvalPlan(valid, "auc"))
        assert all(r.ok and r.score is not None for r in results)
        # per-placement residency: each slice builds its own train entry
        # AND its own eval entry — 4 builds total, the rest are hits
        hits, misses = pool.prepared_cache.counters()
        assert misses == 4
        assert hits == 2 * len(tasks) - misses

    def test_mesh_custom_runner_skips_scoring(self, small_data):
        train, valid = small_data

        def runner(task, sl, data):
            return 0.123, 0.01              # opaque payload (LM loss style)

        pool = MeshSliceExecutorPool(slices=["s0"], task_runner=runner)
        tasks = _tasks("logreg", [{"c": 0.1}])
        [res] = pool.run(schedule(tasks, 1), train, EvalPlan(valid, "auc"))
        assert res.ok and res.score is None and res.model == 0.123

    def test_eval_failure_degrades_to_none_score(self, small_data):
        train, valid = small_data

        class Boom(GBDTModel):
            def predict_proba_jax(self, x, *, cache=None):
                raise RuntimeError("scoring exploded")

            @classmethod
            def predict_proba_batched(cls, models, x, *, cache=None):
                raise RuntimeError("scoring exploded")

        est = get_estimator("gbdt")
        m, _ = est.run(train, {"round": 2, "max_depth": 2, "max_bin": 32})
        boom = Boom(m.feat, m.thresh, m.leaves, m.base, m.max_depth)
        scores, eval_s = evaluate_models(est, [boom], EvalPlan(valid, "auc"))
        assert scores == [None] and eval_s == 0.0


# ---------------------------------------------------------------------------
# scored streaming: no driver-side predict on the streaming path
# ---------------------------------------------------------------------------

class TestScoredStreaming:
    def test_stream_carries_scores_with_poisoned_numpy_predictor(
            self, small_data, monkeypatch):
        train, valid = small_data

        def boom(self, x):
            raise AssertionError("driver-side numpy predict on streaming path")

        monkeypatch.setattr(GBDTModel, "predict_proba", boom)
        monkeypatch.setattr(GBDTModel, "predict_margin", boom)
        monkeypatch.setattr(LogRegModel, "predict_proba", boom)
        spec = SearchSpec(
            spaces=[GridBuilder("gbdt").add_grid("eta", [0.1, 0.3])
                    .add_grid("round", [3, 5]).build(),
                    GridBuilder("logreg").add_grid("c", [0.1, 1.0]).build()],
            n_executors=2, target_metric=0.9999)
        session = Session(spec)
        results = list(session.results(train, valid))
        assert results, "stream yielded nothing"
        assert all(r.ok and r.score is not None for r in results)
        assert session.stats.eval_seconds_total > 0

    def test_target_metric_stops_from_streamed_score(self, small_data):
        train, valid = small_data
        spec = SearchSpec(
            spaces=[GridBuilder("logreg").add_grid(
                "c", [0.1, 0.3, 1.0, 3.0]).build()],
            n_executors=1, target_metric=0.0)   # any score >= 0 stops it
        session = Session(spec)
        results = list(session.results(train, valid))
        assert session.stop_reason == "target_metric"
        assert len(results) < 4

    def test_predict_compile_stats_surface(self, small_data):
        train, valid = small_data
        spec = SearchSpec(
            spaces=[GridBuilder("logreg").add_grid("c", [0.1, 1.0]).build()],
            n_executors=1)
        session = Session(spec)
        list(session.results(train, valid))
        st = session.stats
        assert st.predict_compile_cache_hits + st.predict_compile_cache_misses > 0
        assert 0.0 <= st.predict_compile_cache_hit_rate <= 1.0

    def test_foreign_backend_falls_back_to_driver_scoring(self, small_data):
        """A backend whose submit lacks the validate kwarg still works —
        the Session computes scores driver-side, lazily."""
        train, valid = small_data

        class MinimalBackend:
            def __init__(self):
                from repro.core.fault import SearchWAL

                self.wal = SearchWAL(None)
                self._inner = LocalExecutorPool(1, wal=self.wal)

            n_executors = 1
            dead_executors = frozenset()

            def submit(self, assignment, data):
                return self._inner.submit(assignment, data)

        spec = SearchSpec(
            spaces=[GridBuilder("logreg").add_grid("c", [0.1]).build()],
            n_executors=1, target_metric=0.0)
        session = Session(spec, backend=MinimalBackend())
        results = list(session.results(train, valid))
        assert results and results[0].score is None     # no executor score
        assert session.stop_reason == "target_metric"   # driver fallback


# ---------------------------------------------------------------------------
# eval as a scheduled cost (tentpole part iii)
# ---------------------------------------------------------------------------

class TestEvalLaw:
    def _task(self, tid=0, **params):
        return TrainTask(task_id=tid, estimator="gbdt",
                         params={"round": 10, "max_depth": 4, **params})

    def test_observe_predict_roundtrip(self):
        cm = CostModel()
        t = self._task()
        assert cm.predict_eval(t, 1000) is None
        cm.observe_eval(t, 0.1, 1000)
        cm.observe_eval(t, 0.4, 4000)
        est = cm.predict_eval(t, 2000)
        assert est is not None and 0.1 < est < 0.4
        # monotone in eval rows (law exponents are clamped >= 0)
        assert cm.predict_eval(t, 8000) >= cm.predict_eval(t, 1000)

    def test_bucket_resolution_beats_pooled(self):
        cm = CostModel()
        big = self._task(0, round=90, max_depth=6)
        small = self._task(1, round=10, max_depth=3)
        cm.observe_eval(big, 1.0, 1000)
        cm.observe_eval(small, 0.05, 1000)
        assert cm.predict_eval(big, 1000) == pytest.approx(1.0)
        assert cm.predict_eval(small, 1000) == pytest.approx(0.05)
        # an unseen bucket falls back to the pooled family law
        other = self._task(2, round=30, max_depth=5)
        pooled = cm.predict_eval(other, 1000)
        assert pooled is not None and 0.05 < pooled < 1.0
        # a bare family string reads the pooled law directly
        assert cm.predict_eval("gbdt", 1000) == pytest.approx(pooled)

    def test_eval_law_persists(self, tmp_path):
        path = str(tmp_path / "cm.json")
        cm = CostModel(path)
        t = self._task()
        cm.observe_eval(t, 0.2, 1000)
        cm.save()
        warm = CostModel.open(path)
        assert warm.predict_eval(t, 1000) == pytest.approx(0.2)

    def test_observe_result_feeds_eval_law(self):
        cm = CostModel()
        t = self._task()
        res = TaskResult(task=t, model=object(), train_seconds=1.0,
                         executor_id=0, eval_seconds=0.25)
        cm.observe_result(res, n_rows=5000, eval_rows=1000)
        assert cm.predict_eval(t, 1000) == pytest.approx(0.25)

    def test_charge_units_adds_recurring_cost(self):
        tasks = [TrainTask(task_id=i, estimator="gbdt",
                           params={"round": 10, "max_depth": 4}, cost=2.0)
                 for i in range(3)]
        charged = charge_units(tasks, lambda t: 0.5)
        assert [t.cost for t in charged] == [2.5, 2.5, 2.5]
        # None extra and cost-less units pass through untouched
        uncosted = [TrainTask(task_id=9, estimator="gbdt", params={})]
        assert charge_units(uncosted, lambda t: 0.5)[0].cost is None
        assert charge_units(tasks, lambda t: None)[0].cost == 2.0

    def test_fused_charge_each_survives_split(self):
        tasks = tuple(
            TrainTask(task_id=i, estimator="gbdt",
                      params={"round": r, "max_depth": 4}, cost=1.0)
            for i, r in enumerate((8, 8, 16, 16)))
        unit = FusedBatch(tasks=tasks, signature=("gbdt",),
                          buckets=(8, 8, 16, 16), cost=4.0,
                          prior_costs=(1.0, 1.0, 1.0, 1.0))
        charged = unit.charge_each(lambda m: 0.25)
        assert charged.cost == pytest.approx(5.0)
        pieces = charged.split_at_buckets()
        assert sum(p.cost for p in pieces) == pytest.approx(5.0)
        # a stranded singleton's restored solo cost keeps its eval share
        assert charged.unfused_task(0).cost == pytest.approx(1.25)

    def test_session_drift_window_includes_eval(self, small_data):
        """Planned costs carry predict_eval once the law is warm: second
        session plans with eval included (cost model estimates > 0)."""
        train, valid = small_data
        cm = CostModel()
        spec = SearchSpec(
            spaces=[GridBuilder("logreg").add_grid("c", [0.1, 1.0]).build()],
            n_executors=1, profiler=cm, replan_threshold=100.0)
        s1 = Session(spec)
        list(s1.results(train, valid))
        t = TrainTask(task_id=0, estimator="logreg", params={"c": 0.1})
        assert cm.predict_eval(t, valid.n_rows) is not None


# ---------------------------------------------------------------------------
# MultiModel memoization + ModelScore breakdown (satellite)
# ---------------------------------------------------------------------------

class _CountingModel:
    def __init__(self):
        self.calls = 0

    def predict_proba(self, x):
        self.calls += 1
        return np.linspace(0.1, 0.9, x.shape[0])


class TestMultiModelMemo:
    def _results(self, n=3):
        out = []
        for i in range(n):
            t = TrainTask(task_id=i, estimator="gbdt", params={"i": i})
            out.append(TaskResult(task=t, model=_CountingModel(),
                                  train_seconds=1.0 + i, executor_id=0,
                                  batch_size=2, convert_seconds=0.1 * i,
                                  eval_seconds=0.01 * (i + 1)))
        return out

    def test_validate_all_memoizes_predictions(self, small_data):
        _, valid = small_data
        mm = MultiModel(self._results())
        mm.validate_all(valid, metric="auc")
        mm.validate_all(valid, metric="auc")
        mm.best(valid, metric="auc")
        assert all(r.model.calls == 1 for r in mm.results)
        # a different metric reuses the SAME predictions
        mm.validate_all(valid, metric="accuracy")
        assert all(r.model.calls == 1 for r in mm.results)

    def test_different_data_recomputes(self, small_data):
        train, valid = small_data
        mm = MultiModel(self._results())
        mm.validate_all(valid)
        mm.validate_all(train)
        assert all(r.model.calls == 2 for r in mm.results)

    def test_model_score_carries_breakdown(self, small_data):
        _, valid = small_data
        mm = MultiModel(self._results())
        ranked = mm.validate_all(valid)
        by_id = {s.task.task_id: s for s in ranked}
        assert by_id[1].convert_seconds == pytest.approx(0.1)
        assert by_id[1].eval_seconds == pytest.approx(0.02)
        assert by_id[1].batch_size == 2
        assert by_id[2].train_seconds == pytest.approx(3.0)

    def test_returned_ranking_is_a_copy(self, small_data):
        _, valid = small_data
        mm = MultiModel(self._results())
        first = mm.validate_all(valid)
        first.clear()
        assert len(mm.validate_all(valid)) == 3


# ---------------------------------------------------------------------------
# WAL round trip
# ---------------------------------------------------------------------------

class TestWALEvalFields:
    def test_record_roundtrip(self, tmp_path):
        from repro.core.fault import SearchWAL

        path = str(tmp_path / "wal.jsonl")
        wal = SearchWAL(path)
        wal.record(WALRecord(task_id=1, key="k", seconds=1.0, executor_id=0,
                             score=0.93, convert_seconds=0.1,
                             eval_seconds=0.02))
        again = SearchWAL(path)
        rec = again.completed()[1]
        assert rec.score == pytest.approx(0.93)
        assert rec.eval_seconds == pytest.approx(0.02)

    def test_pre_eval_wal_lines_parse(self, tmp_path):
        path = tmp_path / "wal.jsonl"
        path.write_text('{"task_id": 5, "key": "k", "seconds": 2.0, '
                        '"executor_id": 1, "convert_seconds": 0.5}\n')
        from repro.core.fault import SearchWAL

        rec = SearchWAL(str(path)).completed()[5]
        assert rec.score is None and rec.eval_seconds == 0.0
