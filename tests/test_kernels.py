"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes (the tests/ contract for kernels/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,t,d,causal,window",
    [
        (1, 2, 2, 128, 64, True, None),
        (2, 4, 2, 256, 64, True, None),      # GQA
        (1, 4, 1, 256, 128, True, None),     # MQA
        (1, 2, 2, 256, 64, False, None),     # bidirectional
        (1, 2, 1, 256, 64, True, 64),        # sliding window
    ],
)
def test_flash_attention_vs_ref(rng, b, hq, hkv, t, d, causal, window, dtype):
    q = _rand(rng, (b, hq, t, d), dtype)
    k = _rand(rng, (b, hkv, t, d), dtype)
    v = _rand(rng, (b, hkv, t, d), dtype)
    out_k = ops.attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, force="kernel")
    out_r = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_softcap(rng):
    q = _rand(rng, (1, 2, 128, 64), jnp.float32)
    k = _rand(rng, (1, 2, 128, 64), jnp.float32)
    v = _rand(rng, (1, 2, 128, 64), jnp.float32)
    out_k = ops.attention(q, k, v, logit_softcap=30.0, block_q=64, block_k=64,
                          force="kernel")
    out_r = ref.attention_ref(q, k, v, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


def test_attention_xla_blocked_matches_ref(rng):
    q = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    k = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    v = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    for window in (None, 512):
        blocked = ref.attention_xla_blocked(q, k, v, causal=True, window=window,
                                            block_q=1024)
        full = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                                   atol=3e-5, rtol=1e-4)


def test_decode_attention_matches_prefix(rng):
    """Decode over a cache == last row of full attention."""
    b, hq, hkv, t, d = 2, 4, 2, 64, 32
    q_all = _rand(rng, (b, hq, t, d), jnp.float32)
    k_all = _rand(rng, (b, hkv, t, d), jnp.float32)
    v_all = _rand(rng, (b, hkv, t, d), jnp.float32)
    full = ref.attention_ref(q_all, k_all, v_all, causal=True)
    cache_k = jnp.pad(k_all, ((0, 0), (0, 0), (0, 16), (0, 0)))
    cache_v = jnp.pad(v_all, ((0, 0), (0, 0), (0, 16), (0, 0)))
    dec = ref.decode_attention_ref(q_all[:, :, -1:], cache_k, cache_v, t)
    np.testing.assert_allclose(np.asarray(dec[:, :, 0]), np.asarray(full[:, :, -1]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d", [(1, 64, 128), (2, 128, 256), (1, 8, 128)])
def test_rglru_vs_ref(rng, b, t, d):
    x = _rand(rng, (b, t, d), jnp.float32)
    ig = _rand(rng, (b, t, d), jnp.float32)
    rg_ = _rand(rng, (b, t, d), jnp.float32)
    a = _rand(rng, (d,), jnp.float32)
    yk, hk = ops.rglru(x, ig, rg_, a, force="kernel")
    yr, hr = ref.rglru_ref(x, ig, rg_, a)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=2e-5)


def test_rglru_state_chaining(rng):
    """Running [0:T] == running [0:T/2] then [T/2:T] with carried state."""
    b, t, d = 1, 64, 128
    x = _rand(rng, (b, t, d), jnp.float32)
    ig = _rand(rng, (b, t, d), jnp.float32)
    rg_ = _rand(rng, (b, t, d), jnp.float32)
    a = _rand(rng, (d,), jnp.float32)
    y_full, h_full = ref.rglru_ref(x, ig, rg_, a)
    h = None
    ys = []
    for lo, hi in ((0, t // 2), (t // 2, t)):
        y, h = ref.rglru_ref(x[:, lo:hi], ig[:, lo:hi], rg_[:, lo:hi], a, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,dk,dv,chunk", [
    (1, 2, 64, 32, 32, 16),
    (2, 2, 128, 64, 64, 64),
    (1, 1, 96, 16, 64, 32),
])
def test_rwkv6_vs_ref(rng, b, h, t, dk, dv, chunk):
    r = _rand(rng, (b, h, t, dk), jnp.float32)
    k = _rand(rng, (b, h, t, dk), jnp.float32)
    v = _rand(rng, (b, h, t, dv), jnp.float32)
    w = _rand(rng, (b, h, t, dk), jnp.float32)
    u = _rand(rng, (h, dk), jnp.float32)
    yk, sk = ops.rwkv6(r, k, v, w, u, chunk=chunk, force="kernel")
    yr, sr = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=5e-3)


def test_rwkv6_state_chaining(rng):
    b, h, t, dk, dv = 1, 2, 64, 32, 32
    r = _rand(rng, (b, h, t, dk), jnp.float32)
    k = _rand(rng, (b, h, t, dk), jnp.float32)
    v = _rand(rng, (b, h, t, dv), jnp.float32)
    w = _rand(rng, (b, h, t, dk), jnp.float32)
    u = _rand(rng, (h, dk), jnp.float32)
    y_full, s_full = ref.rwkv6_ref(r, k, v, w, u)
    s = None
    ys = []
    for lo, hi in ((0, 32), (32, 64)):
        y, s = ref.rwkv6_ref(r[:, :, lo:hi], k[:, :, lo:hi], v[:, :, lo:hi],
                             w[:, :, lo:hi], u, s)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 2)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,f,nb,nn", [(100, 5, 8, 1), (500, 7, 16, 4), (1000, 3, 64, 8)])
def test_histogram_all_paths_agree(rng, r, f, nb, nn):
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    oracle = ref.histogram_ref(bins, g, h, node, nn, nb)
    kernel = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb, force="kernel")
    scatter = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(oracle), atol=1e-4)
    np.testing.assert_allclose(np.asarray(scatter), np.asarray(oracle), atol=1e-4)


def test_histogram_conservation(rng):
    """Σ over all cells of the grad histogram == Σ grads (per feature)."""
    r, f, nb, nn = 300, 4, 16, 4
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.ones((r,), jnp.float32)
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    hist = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb, force="kernel")
    total_g = np.asarray(hist[..., 0].sum(axis=(0, 2)))
    np.testing.assert_allclose(total_g, float(g.sum()) * np.ones(f), rtol=1e-4)
    total_h = np.asarray(hist[..., 1].sum(axis=(0, 2)))
    np.testing.assert_allclose(total_h, r * np.ones(f), rtol=1e-5)


def test_histogram_tile_table_respects_vmem_budget():
    """pick_tiles shrinks block_features as n_nodes grows: the two f32 VMEM
    accumulators (2·N·bf·B·4 bytes) must stay inside the scratch budget at
    every tree level, not just the shallow ones the sweep measured."""
    from repro.kernels.histogram import _VMEM_SCRATCH_BUDGET, pick_tiles

    for n_bins in (32, 64, 128, 256):
        for n_nodes in (1, 8, 64, 512, 2048):
            bf, br = pick_tiles(120, n_bins, 4800, n_nodes=n_nodes)
            assert bf >= 1 and br >= 8
            assert (bf == 1
                    or 2 * n_nodes * bf * n_bins * 4 <= _VMEM_SCRATCH_BUDGET)
    # deep level really does shrink vs the table default
    assert pick_tiles(120, 64, 4800, n_nodes=2048)[0] < \
        pick_tiles(120, 64, 4800, n_nodes=8)[0]


def test_pick_tiles_never_exceeds_rows(rng):
    """Regression: ``min(block_r, max(8, n_rows))`` returned block_rows=8
    for a 4-row histogram, silently padding tiny arrays — block_rows must
    be clamped to the array."""
    from repro.kernels.histogram import pick_tiles

    for n_rows in (1, 4, 7):
        _, br = pick_tiles(16, 64, n_rows)
        assert br == n_rows
    _, br = pick_tiles(16, 64, 4800)
    assert br == 512                       # table default untouched
    # and a 4-row histogram actually computes correctly through the kernel
    r, f, nb, nn = 4, 3, 8, 2
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    from repro.kernels.histogram import histogram_tpu

    kern = histogram_tpu(bins, g, h, node, n_nodes=nn, n_bins=nb,
                         interpret=True)
    np.testing.assert_allclose(
        np.asarray(kern), np.asarray(ref.histogram_ref(bins, g, h, node, nn, nb)),
        atol=1e-4)
