"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes (the tests/ contract for kernels/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,t,d,causal,window",
    [
        (1, 2, 2, 128, 64, True, None),
        (2, 4, 2, 256, 64, True, None),      # GQA
        (1, 4, 1, 256, 128, True, None),     # MQA
        (1, 2, 2, 256, 64, False, None),     # bidirectional
        (1, 2, 1, 256, 64, True, 64),        # sliding window
    ],
)
def test_flash_attention_vs_ref(rng, b, hq, hkv, t, d, causal, window, dtype):
    q = _rand(rng, (b, hq, t, d), dtype)
    k = _rand(rng, (b, hkv, t, d), dtype)
    v = _rand(rng, (b, hkv, t, d), dtype)
    out_k = ops.attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, force="kernel")
    out_r = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_softcap(rng):
    q = _rand(rng, (1, 2, 128, 64), jnp.float32)
    k = _rand(rng, (1, 2, 128, 64), jnp.float32)
    v = _rand(rng, (1, 2, 128, 64), jnp.float32)
    out_k = ops.attention(q, k, v, logit_softcap=30.0, block_q=64, block_k=64,
                          force="kernel")
    out_r = ref.attention_ref(q, k, v, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


def test_attention_xla_blocked_matches_ref(rng):
    q = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    k = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    v = _rand(rng, (1, 2, 4096, 64), jnp.float32)
    for window in (None, 512):
        blocked = ref.attention_xla_blocked(q, k, v, causal=True, window=window,
                                            block_q=1024)
        full = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                                   atol=3e-5, rtol=1e-4)


def test_decode_attention_matches_prefix(rng):
    """Decode over a cache == last row of full attention."""
    b, hq, hkv, t, d = 2, 4, 2, 64, 32
    q_all = _rand(rng, (b, hq, t, d), jnp.float32)
    k_all = _rand(rng, (b, hkv, t, d), jnp.float32)
    v_all = _rand(rng, (b, hkv, t, d), jnp.float32)
    full = ref.attention_ref(q_all, k_all, v_all, causal=True)
    cache_k = jnp.pad(k_all, ((0, 0), (0, 0), (0, 16), (0, 0)))
    cache_v = jnp.pad(v_all, ((0, 0), (0, 0), (0, 16), (0, 0)))
    dec = ref.decode_attention_ref(q_all[:, :, -1:], cache_k, cache_v, t)
    np.testing.assert_allclose(np.asarray(dec[:, :, 0]), np.asarray(full[:, :, -1]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,d", [(1, 64, 128), (2, 128, 256), (1, 8, 128)])
def test_rglru_vs_ref(rng, b, t, d):
    x = _rand(rng, (b, t, d), jnp.float32)
    ig = _rand(rng, (b, t, d), jnp.float32)
    rg_ = _rand(rng, (b, t, d), jnp.float32)
    a = _rand(rng, (d,), jnp.float32)
    yk, hk = ops.rglru(x, ig, rg_, a, force="kernel")
    yr, hr = ref.rglru_ref(x, ig, rg_, a)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=2e-5)


def test_rglru_state_chaining(rng):
    """Running [0:T] == running [0:T/2] then [T/2:T] with carried state."""
    b, t, d = 1, 64, 128
    x = _rand(rng, (b, t, d), jnp.float32)
    ig = _rand(rng, (b, t, d), jnp.float32)
    rg_ = _rand(rng, (b, t, d), jnp.float32)
    a = _rand(rng, (d,), jnp.float32)
    y_full, h_full = ref.rglru_ref(x, ig, rg_, a)
    h = None
    ys = []
    for lo, hi in ((0, t // 2), (t // 2, t)):
        y, h = ref.rglru_ref(x[:, lo:hi], ig[:, lo:hi], rg_[:, lo:hi], a, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,t,dk,dv,chunk", [
    (1, 2, 64, 32, 32, 16),
    (2, 2, 128, 64, 64, 64),
    (1, 1, 96, 16, 64, 32),
])
def test_rwkv6_vs_ref(rng, b, h, t, dk, dv, chunk):
    r = _rand(rng, (b, h, t, dk), jnp.float32)
    k = _rand(rng, (b, h, t, dk), jnp.float32)
    v = _rand(rng, (b, h, t, dv), jnp.float32)
    w = _rand(rng, (b, h, t, dk), jnp.float32)
    u = _rand(rng, (h, dk), jnp.float32)
    yk, sk = ops.rwkv6(r, k, v, w, u, chunk=chunk, force="kernel")
    yr, sr = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=5e-3)


def test_rwkv6_state_chaining(rng):
    b, h, t, dk, dv = 1, 2, 64, 32, 32
    r = _rand(rng, (b, h, t, dk), jnp.float32)
    k = _rand(rng, (b, h, t, dk), jnp.float32)
    v = _rand(rng, (b, h, t, dv), jnp.float32)
    w = _rand(rng, (b, h, t, dk), jnp.float32)
    u = _rand(rng, (h, dk), jnp.float32)
    y_full, s_full = ref.rwkv6_ref(r, k, v, w, u)
    s = None
    ys = []
    for lo, hi in ((0, 32), (32, 64)):
        y, s = ref.rwkv6_ref(r[:, :, lo:hi], k[:, :, lo:hi], v[:, :, lo:hi],
                             w[:, :, lo:hi], u, s)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 2)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full), atol=1e-4)


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,f,nb,nn", [(100, 5, 8, 1), (500, 7, 16, 4), (1000, 3, 64, 8)])
def test_histogram_all_paths_agree(rng, r, f, nb, nn):
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    oracle = ref.histogram_ref(bins, g, h, node, nn, nb)
    kernel = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb, force="kernel")
    scatter = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(oracle), atol=1e-4)
    np.testing.assert_allclose(np.asarray(scatter), np.asarray(oracle), atol=1e-4)


def test_histogram_conservation(rng):
    """Σ over all cells of the grad histogram == Σ grads (per feature)."""
    r, f, nb, nn = 300, 4, 16, 4
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.ones((r,), jnp.float32)
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    hist = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb, force="kernel")
    total_g = np.asarray(hist[..., 0].sum(axis=(0, 2)))
    np.testing.assert_allclose(total_g, float(g.sum()) * np.ones(f), rtol=1e-4)
    total_h = np.asarray(hist[..., 1].sum(axis=(0, 2)))
    np.testing.assert_allclose(total_h, r * np.ones(f), rtol=1e-5)


def test_histogram_tile_table_respects_vmem_budget():
    """pick_tiles shrinks block_features as n_nodes grows: the two f32 VMEM
    accumulators (2·N·bf·B·4 bytes) must stay inside the scratch budget at
    every tree level, not just the shallow ones the sweep measured."""
    from repro.kernels.histogram import _VMEM_SCRATCH_BUDGET, pick_tiles

    for n_bins in (32, 64, 128, 256):
        for n_nodes in (1, 8, 64, 512, 2048):
            bf, br = pick_tiles(120, n_bins, 4800, n_nodes=n_nodes)
            assert bf >= 1 and br >= 8
            assert (bf == 1
                    or 2 * n_nodes * bf * n_bins * 4 <= _VMEM_SCRATCH_BUDGET)
    # deep level really does shrink vs the table default
    assert pick_tiles(120, 64, 4800, n_nodes=2048)[0] < \
        pick_tiles(120, 64, 4800, n_nodes=8)[0]


def test_histogram_kernel_odd_feature_and_bin_shapes(rng):
    """Padded-tile audit (feature/bin axes, the PR 5 row-clamp pattern):
    a feature count that doesn't divide block_features pads inside the
    kernel and MUST be trimmed from the result; a bin count with no exact
    tile-table key goes through the nearest-key lookup. Either leak would
    change the output shape or pollute real cells."""
    for r, f, nb, nn in [(50, 19, 24, 3), (128, 13, 48, 5), (37, 9, 8, 2)]:
        bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
        g = _rand(rng, (r,), jnp.float32)
        h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
        node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
        kern = ops.histogram(bins, g, h, node, n_nodes=nn, n_bins=nb,
                             force="kernel")
        assert kern.shape == (nn, f, nb, 2)
        np.testing.assert_allclose(
            np.asarray(kern),
            np.asarray(ref.histogram_ref(bins, g, h, node, nn, nb)), atol=1e-4)


def test_pick_tiles_never_exceeds_rows(rng):
    """Regression: ``min(block_r, max(8, n_rows))`` returned block_rows=8
    for a 4-row histogram, silently padding tiny arrays — block_rows must
    be clamped to the array."""
    from repro.kernels.histogram import pick_tiles

    for n_rows in (1, 4, 7):
        _, br = pick_tiles(16, 64, n_rows)
        assert br == n_rows
    _, br = pick_tiles(16, 64, 4800)
    assert br == 1024                      # table default untouched
    # and a 4-row histogram actually computes correctly through the kernel
    r, f, nb, nn = 4, 3, 8, 2
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    from repro.kernels.histogram import histogram_tpu

    kern = histogram_tpu(bins, g, h, node, n_nodes=nn, n_bins=nb,
                         interpret=True)
    np.testing.assert_allclose(
        np.asarray(kern), np.asarray(ref.histogram_ref(bins, g, h, node, nn, nb)),
        atol=1e-4)


# ---------------------------------------------------------------------------
# fused level split (histogram + split scan + subtraction, DESIGN.md §3.8)
# ---------------------------------------------------------------------------

def _level_fixture(rng, r, f, nb, nn):
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    return bins, g, h, node


def _parent_of(bins, g, h, node, nn, nb):
    """Level-above histograms over the same rows (node // 2)."""
    return ops._histogram_scatter(bins, g, h, node // 2, nn // 2, nb)


# the ISSUE parity grid: depths {1, 3, 6} (n_nodes = 2^(depth-1) at the
# deepest level) × bins {16, 64, 256}
@pytest.mark.parametrize("r,f,nb,nn", [
    (200, 5, 16, 1), (500, 7, 64, 4), (400, 12, 256, 4), (300, 9, 16, 32),
    (600, 3, 64, 32), (250, 6, 256, 32),
])
def test_level_split_kernel_vs_ref(rng, r, f, nb, nn):
    bins, g, h, node = _level_fixture(rng, r, f, nb, nn)
    kw = dict(n_nodes=nn, n_bins=nb, lam=1.0, min_child_weight=1.0)
    hk, bgk, bfk, bsk = ops.level_split(bins, g, h, node, force="kernel", **kw)
    hr, bgr, bfr, bsr = ops.level_split(bins, g, h, node, force="ref", **kw)
    hx, bgx, bfx, bsx = ops.level_split(bins, g, h, node, **kw)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=1e-4)
    for bf, bs in ((bfk, bsk), (bfx, bsx)):
        assert bool((bf == bfr).all() and (bs == bsr).all())
    finite = np.isfinite(np.asarray(bgr))
    np.testing.assert_allclose(np.asarray(bgk)[finite], np.asarray(bgr)[finite],
                               rtol=1e-4, atol=1e-4)
    # subtraction modes (XLA + kernel) must reproduce the direct decisions
    if nn > 1:
        parent = _parent_of(bins, g, h, node, nn, nb)
        for force in (None, "kernel"):
            hs, _, bfs, bss = ops.level_split(
                bins, g, h, node, parent_hist=parent, force=force, **kw)
            np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
            assert bool((bfs == bfr).all() and (bss == bsr).all())


def test_level_split_traced_bin_limit(rng):
    """bin_limit arrives as a traced int under jit (the fused-batch
    contract): splits at bins >= bin_limit - 1 must never win, and kernel
    and ref must agree under the same traced value."""
    bins, g, h, node = _level_fixture(rng, 400, 6, 64, 8)

    def make(force):
        @jax.jit
        def run(blim):
            return ops.level_split(
                bins, g, h, node, n_nodes=8, n_bins=64, lam=jnp.float32(0.5),
                min_child_weight=jnp.float32(1.0), bin_limit=blim,
                force=force)[1:]
        return run

    for force in ("kernel", "ref", None):
        bg, bf, bs = make(force)(jnp.int32(16))
        assert bool((np.asarray(bs) < 15).all())
    bg_k, bf_k, bs_k = make("kernel")(jnp.int32(16))
    bg_r, bf_r, bs_r = make("ref")(jnp.int32(16))
    assert bool((bf_k == bf_r).all() and (bs_k == bs_r).all())


def test_level_split_feat_mask(rng):
    """Masked-off features (the forest √F subset) never produce a winning
    split on any backend; parity holds under the mask."""
    bins, g, h, node = _level_fixture(rng, 500, 10, 32, 8)
    mask = jnp.asarray(np.arange(10) % 3 == 0)     # features 0,3,6,9 allowed
    kw = dict(n_nodes=8, n_bins=32, lam=1.0, min_child_weight=1.0,
              feat_mask=mask)
    _, bg_r, bf_r, bs_r = ops.level_split(bins, g, h, node, force="ref", **kw)
    for force in ("kernel", None):
        _, bg, bf, bs = ops.level_split(bins, g, h, node, force=force, **kw)
        assert bool((bf == bf_r).all() and (bs == bs_r).all())
        real = np.isfinite(np.asarray(bg))
        assert bool(np.asarray(mask)[np.asarray(bf)[real]].all())


def test_level_split_subtraction_bit_equality_integer_stats(rng):
    """With integer-valued g/h every histogram sum is exact in f32, so
    ``parent − small`` is genuinely bit-equal to the direct build — this
    pins the subtraction indexing/assembly (smaller-child choice, row
    compaction, sibling interleave) with zero float slack, on both the XLA
    fallback and the fused kernel."""
    r, f, nb, nn = 600, 5, 32, 16
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = jnp.asarray(rng.integers(-8, 9, size=r), jnp.float32)
    h = jnp.asarray(rng.integers(1, 5, size=r), jnp.float32)
    node = jnp.asarray(rng.integers(0, nn, size=(r,)), jnp.int32)
    kw = dict(n_nodes=nn, n_bins=nb, lam=1.0, min_child_weight=1.0)
    parent = _parent_of(bins, g, h, node, nn, nb)
    hd, _, _, _ = ops.level_split(bins, g, h, node, **kw)
    for force in (None, "kernel"):
        hs, _, _, _ = ops.level_split(bins, g, h, node, parent_hist=parent,
                                      force=force, **kw)
        assert bool((np.asarray(hs) == np.asarray(hd)).all())


def test_level_split_empty_sibling_exact(rng):
    """Sentinel-split parents route every row LEFT, so the right child is
    empty and subtraction returns ``parent − 0`` — bit-exact even with
    real-valued g/h. This is what keeps depth_limit-padded levels identical
    between the subtraction and direct paths."""
    r, f, nb, nn = 300, 4, 16, 8
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    g = _rand(rng, (r,), jnp.float32)
    h = jnp.abs(_rand(rng, (r,), jnp.float32)) + 0.1
    node = jnp.asarray(2 * rng.integers(0, nn // 2, size=r), jnp.int32)  # even
    kw = dict(n_nodes=nn, n_bins=nb, lam=1.0, min_child_weight=1.0)
    parent = _parent_of(bins, g, h, node, nn, nb)
    hd, _, _, _ = ops.level_split(bins, g, h, node, **kw)
    for force in (None, "kernel"):
        hs, _, _, _ = ops.level_split(bins, g, h, node, parent_hist=parent,
                                      force=force, **kw)
        assert bool((np.asarray(hs) == np.asarray(hd)).all())


def test_level_split_return_hist_false_same_decisions(rng):
    bins, g, h, node = _level_fixture(rng, 200, 5, 16, 4)
    kw = dict(n_nodes=4, n_bins=16, lam=1.0, min_child_weight=1.0)
    for force in ("kernel", None, "ref"):
        full = ops.level_split(bins, g, h, node, force=force, **kw)
        slim = ops.level_split(bins, g, h, node, force=force,
                               return_hist=False, **kw)
        assert slim[0] is None
        for a, b in zip(full[1:], slim[1:]):
            assert bool((np.asarray(a) == np.asarray(b)).all())


def test_level_split_kernel_under_vmap(rng):
    """The fused-batch path vmaps build_tree over traced scalars; the
    kernel must map correctly over a batch of (g, h, node, lam)."""
    r, f, nb, nn, b = 160, 4, 16, 4, 3
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    gs = jnp.asarray(rng.normal(size=(b, r)), jnp.float32)
    hs = jnp.asarray(rng.random((b, r)) + 0.1, jnp.float32)
    nodes = jnp.asarray(rng.integers(0, nn, size=(b, r)), jnp.int32)
    lams = jnp.asarray([0.5, 1.0, 2.0], jnp.float32)

    def one(g, h, node, lam, force):
        return ops.level_split(bins, g, h, node, n_nodes=nn, n_bins=nb,
                               lam=lam, min_child_weight=1.0, force=force)

    out_k = jax.vmap(lambda g, h, n, l: one(g, h, n, l, "kernel"))(
        gs, hs, nodes, lams)
    out_r = jax.vmap(lambda g, h, n, l: one(g, h, n, l, "ref"))(
        gs, hs, nodes, lams)
    np.testing.assert_allclose(np.asarray(out_k[0]), np.asarray(out_r[0]),
                               atol=1e-4)
    assert bool((out_k[2] == out_r[2]).all() and (out_k[3] == out_r[3]).all())


@pytest.mark.parametrize("depth,nb", [(1, 16), (3, 64), (6, 256), (6, 16)])
def test_build_tree_subtraction_parity(rng, depth, nb):
    """The acceptance grid: build_tree with histogram subtraction (the
    training default) is bit-identical — feat, split, leaf sums — to the
    pre-subtraction direct path, across depths × bin counts."""
    from repro.tabular.gbdt import build_tree

    r, f = 600, 8
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 2, size=r), jnp.float32)
    p = jax.nn.sigmoid(jnp.asarray(rng.normal(size=r), jnp.float32))
    g, h = p - y, jnp.maximum(p * (1 - p), 1e-16)

    import functools as ft
    run = lambda sub: jax.jit(ft.partial(
        build_tree, n_bins=nb, max_depth=depth, lam=1.0, gamma=0.0,
        min_child_weight=1.0, subtract=sub))(bins, g, h)
    for a, b in zip(run(True), run(False)):
        assert bool((np.asarray(a) == np.asarray(b)).all())


def test_build_tree_subtraction_parity_traced_limits_and_mask(rng):
    """Same bit-identity with the fused-batch knobs engaged: traced
    depth_limit/bin_limit plus a forest-style feature mask."""
    from repro.tabular.gbdt import build_tree

    r, f, nb, depth = 500, 10, 64, 5
    bins = jnp.asarray(rng.integers(0, nb, size=(r, f)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 2, size=r), jnp.float32)
    p = jax.nn.sigmoid(jnp.asarray(rng.normal(size=r), jnp.float32))
    g, h = p - y, jnp.maximum(p * (1 - p), 1e-16)
    mask = jnp.asarray(np.arange(f) % 2 == 0)

    def make(sub):
        @jax.jit
        def run(dlim, blim):
            return build_tree(
                bins, g, h, n_bins=nb, max_depth=depth, lam=jnp.float32(1.0),
                gamma=jnp.float32(0.0), min_child_weight=jnp.float32(1.0),
                feat_mask=mask, depth_limit=dlim, bin_limit=blim,
                subtract=sub)
        return run

    run_sub, run_dir = make(True), make(False)
    for dlim, blim in ((jnp.int32(3), jnp.int32(32)),
                       (jnp.int32(5), jnp.int32(64))):
        for a, b in zip(run_sub(dlim, blim), run_dir(dlim, blim)):
            assert bool((np.asarray(a) == np.asarray(b)).all())
        # structural masking honoured: no split bin past the traced limit
        split = np.asarray(run_sub(dlim, blim)[1])
        assert bool(((split < int(blim) - 1) | (split == nb - 1)).all())
