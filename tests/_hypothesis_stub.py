"""Minimal deterministic stand-in for `hypothesis` (offline containers).

Test modules fall back to this when the real library is not installed:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st

It implements only the surface our tests use — ``@given`` with positional or
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and
``st.lists`` / ``st.floats`` / ``st.integers`` / ``st.sampled_from``. Every
example is drawn from a seeded RNG, so runs are reproducible; shrinking and
the example database are deliberately absent. Draws are biased toward
boundaries and small sizes, which is where scheduler/estimator bugs live.
"""
from __future__ import annotations

import functools
import inspect
import math
import random

_MAX_EXAMPLES_CAP = 100  # keep CI time bounded even if a test asks for more


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class st:
    """Namespace mirroring hypothesis.strategies (the parts we use)."""

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, allow_nan=False, allow_infinity=False):
        def draw(rng):
            r = rng.random()
            if r < 0.05:
                return min_value
            if r < 0.10:
                return max_value
            if min_value > 0 and r < 0.55:  # log-uniform across the range
                return math.exp(rng.uniform(math.log(min_value), math.log(max_value)))
            return rng.uniform(min_value, max_value)

        return _Strategy(draw)

    @staticmethod
    def integers(min_value, max_value):
        def draw(rng):
            r = rng.random()
            if r < 0.05:
                return min_value
            if r < 0.10:
                return max_value
            return rng.randint(min_value, max_value)

        return _Strategy(draw)

    @staticmethod
    def lists(elements: _Strategy, min_size=0, max_size=10):
        def draw(rng):
            size = min_size + int((max_size - min_size) * rng.random() ** 2)
            return [elements.example(rng) for _ in range(size)]

        return _Strategy(draw)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)

        def draw(rng):
            return rng.choice(seq)

        return _Strategy(draw)


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        names = list(inspect.signature(fn).parameters)
        mapped = dict(zip(names[: len(arg_strategies)], arg_strategies))
        mapped.update(kw_strategies)
        fixtures = [n for n in names if n not in mapped]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_stub_max_examples", 30), _MAX_EXAMPLES_CAP)
            for i in range(n):
                rng = random.Random(0xC0FFEE + 7919 * i)
                drawn = {name: s.example(rng) for name, s in mapped.items()}
                fn(*args, **drawn, **kwargs)

        # hide the drawn params from pytest so only real fixtures remain
        wrapper.__signature__ = inspect.Signature(
            [inspect.Parameter(n, inspect.Parameter.POSITIONAL_OR_KEYWORD)
             for n in fixtures]
        )
        return wrapper

    return decorate


def settings(max_examples: int = 30, deadline=None, **_ignored):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate
