"""Budgeted shared caches (DESIGN.md §3.5): byte budgets + LRU eviction on
PreparedDataCache/CompileCache, pin/in-flight protection, exactly-once
rebuild of evicted entries, and the per-tenant ledger invariant — tenant
sums equal the global counters EXACTLY, even under thread churn."""
import threading

import numpy as np
import pytest

from repro.core.data_format import PreparedDataCache, payload_nbytes
from repro.core.fusion import DEFAULT_PROGRAM_NBYTES, CompileCache
from repro.core.tenancy import TenantLedger, current_tenant, tenant_context


def _payload(nbytes: int, fill: int = 0) -> dict:
    return {"x": np.full(nbytes, fill, dtype=np.uint8)}


# ---------------------------------------------------------------------------
# PreparedDataCache: budget + LRU
# ---------------------------------------------------------------------------

def test_prepared_budget_evicts_lru_first():
    c = PreparedDataCache(budget_bytes=250)
    for k in ("a", "b", "c"):
        c.get(k, lambda: _payload(100))
    # 300 > 250: the LRU entry ("a") was evicted, most-recent two remain
    assert not c.contains("a")
    assert c.contains("b") and c.contains("c")
    assert c.evictions == 1
    assert c.bytes_cached == 200
    assert c.bytes_built == 300            # monotone, unaffected by eviction

    # a GET refreshes recency: touch "b", insert "d" -> victim is "c"
    c.get("b", lambda: _payload(100))
    c.get("d", lambda: _payload(100))
    assert c.contains("b") and c.contains("d") and not c.contains("c")


def test_prepared_over_budget_single_entry_still_serves():
    c = PreparedDataCache(budget_bytes=10)
    v, secs, built = c.get("big", lambda: _payload(100))
    assert built and payload_nbytes(v) == 100
    # over budget but nothing else to evict and `keep` protects the insert
    assert c.contains("big")
    # the next insert evicts it
    c.get("big2", lambda: _payload(100))
    assert not c.contains("big") and c.contains("big2")


def test_prepared_pinned_entry_survives_eviction():
    c = PreparedDataCache(budget_bytes=250)
    c.get("a", lambda: _payload(100))
    c.pin("a")
    c.get("b", lambda: _payload(100))
    c.get("c", lambda: _payload(100))      # over budget; LRU is "a" but pinned
    assert c.contains("a") and not c.contains("b")
    c.unpin("a")
    c.get("d", lambda: _payload(100))      # over budget again; "a" now evictable
    assert not c.contains("a")
    assert c.bytes_cached <= 250


def test_prepared_inflight_build_is_not_a_victim():
    c = PreparedDataCache(budget_bytes=150)
    release = threading.Event()
    started = threading.Event()

    def slow():
        started.set()
        release.wait(5)
        return _payload(100)

    t = threading.Thread(target=lambda: c.get("slow", slow))
    t.start()
    started.wait(5)
    # while "slow" is mid-build (not ready), pressure the budget hard:
    # the in-flight entry must never be chosen as a victim
    c.get("x", lambda: _payload(100))
    c.get("y", lambda: _payload(100))
    release.set()
    t.join(5)
    assert c.contains("slow")
    v, secs, built = c.get("slow", lambda: pytest.fail("must be resident"))
    assert not built and payload_nbytes(v) == 100


def test_evicted_entry_rebuilds_exactly_once_bit_identical():
    """Satellite 4: fill past budget, lose a variant, then N threads re-request
    it — the in-flight de-dup applies to the REBUILD too (one builder call),
    and the rebuilt payload is bit-identical to the original."""
    c = PreparedDataCache(budget_bytes=250)
    rng = np.random.default_rng(3)
    blob = rng.integers(0, 255, size=100, dtype=np.uint8)
    builds = []

    def build_k():
        builds.append(1)
        return {"x": blob.copy()}

    original, _, built = c.get("k", build_k)
    assert built and len(builds) == 1
    c.get("f1", lambda: _payload(100))
    c.get("f2", lambda: _payload(100))     # "k" is LRU -> evicted
    assert not c.contains("k")

    results = []
    def re_get():
        v, _, _ = c.get("k", build_k)
        results.append(v)
    threads = [threading.Thread(target=re_get) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert len(builds) == 2                # exactly ONE rebuild for 8 racers
    assert len(results) == 8
    for v in results:
        assert v is results[0]             # all served the same entry
    np.testing.assert_array_equal(results[0]["x"], original["x"])


def test_prepared_set_budget_none_disables_eviction():
    c = PreparedDataCache(budget_bytes=100)
    c.get("a", lambda: _payload(90))
    c.set_budget(None)
    for k in ("b", "c", "d"):
        c.get(k, lambda: _payload(90))
    assert c.n_entries == 4 and c.evictions == 0
    c.set_budget(100)                      # re-arming evicts down immediately
    assert c.bytes_cached <= 100


# ---------------------------------------------------------------------------
# CompileCache: budget with nominal program weights
# ---------------------------------------------------------------------------

def test_compile_cache_budget_and_nominal_weight():
    c = CompileCache(name="t", budget_bytes=3 * DEFAULT_PROGRAM_NBYTES)
    for k in ("p0", "p1", "p2", "p3"):     # 4 programs, budget fits 3
        c.get(k, lambda: (lambda: None))
    assert c.evictions == 1
    assert c.n_entries == 3
    assert not c.contains("p0") and c.contains("p3")
    assert c.bytes_cached == 3 * DEFAULT_PROGRAM_NBYTES
    # explicit nbytes overrides the nominal weight
    c.get("fat", lambda: (lambda: None), nbytes=3 * DEFAULT_PROGRAM_NBYTES)
    assert c.contains("fat") and c.n_entries == 1


def test_compile_cache_hit_refreshes_recency_and_pins_protect():
    c = CompileCache(name="t", budget_bytes=2 * DEFAULT_PROGRAM_NBYTES)
    c.get("a", lambda: (lambda: None))
    c.get("b", lambda: (lambda: None))
    c.get("a", lambda: pytest.fail("hit"))   # refresh "a"
    c.get("c", lambda: (lambda: None))       # victim: "b"
    assert c.contains("a") and not c.contains("b")
    c.pin("a")
    c.get("d", lambda: (lambda: None))       # LRU "a" pinned -> "c" goes
    assert c.contains("a") and not c.contains("c")
    c.unpin("a")


# ---------------------------------------------------------------------------
# Tenant ledger: exact accounting (satellite 2)
# ---------------------------------------------------------------------------

def test_tenant_context_nests_and_restores():
    assert current_tenant() == "-"
    with tenant_context("alice"):
        assert current_tenant() == "alice"
        with tenant_context("bob"):
            assert current_tenant() == "bob"
        assert current_tenant() == "alice"
    assert current_tenant() == "-"


def test_tenant_ledger_counts_and_snapshot_isolation():
    led = TenantLedger()
    led.add("hits", tenant="a")
    led.add("hits", 2, tenant="a")
    led.add("bytes", 100, tenant="b")
    snap = led.snapshot()
    assert snap == {"a": {"hits": 3}, "b": {"bytes": 100}}
    snap["a"]["hits"] = 999                # deep copy: mutating it is harmless
    assert led.total("hits") == 3
    assert led.total("bytes") == 100


def test_prepared_cache_attributes_to_current_tenant():
    c = PreparedDataCache()
    with tenant_context("alice"):
        c.get("k", lambda: _payload(50))   # alice pays the miss + bytes
    with tenant_context("bob"):
        c.get("k", lambda: pytest.fail("resident"))   # bob gets the hit
    c.get("k", lambda: None)               # untenanted hit -> "-" bucket
    snap = c.tenant_counters()
    assert snap["alice"] == {"misses": 1, "bytes": 50}
    assert snap["bob"] == {"hits": 1}
    assert snap["-"] == {"hits": 1}


@pytest.mark.parametrize("cache_kind", ["prepared", "compile"])
def test_tenant_sums_equal_globals_under_thread_churn(cache_kind):
    """8 threads x 4 tenants hammer one cache with overlapping keys; every
    hit/miss lands on some tenant's ledger in the same critical section as
    the global counter, so the sums match EXACTLY — no drift, no sampling."""
    if cache_kind == "prepared":
        cache = PreparedDataCache(budget_bytes=64 * 40)
        def touch(k):
            cache.get(k, lambda: _payload(64))
    else:
        cache = CompileCache(name="t", budget_bytes=40 * DEFAULT_PROGRAM_NBYTES)
        def touch(k):
            cache.get(k, lambda: (lambda: None))

    barrier = threading.Barrier(8)
    def worker(i):
        tenant = f"t{i % 4}"
        barrier.wait()
        with tenant_context(tenant):
            for j in range(200):
                touch(f"key-{(i * 7 + j) % 60}")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    hits, misses = cache.counters()
    snap = cache.tenant_counters()
    assert set(snap) == {"t0", "t1", "t2", "t3"}
    assert sum(v.get("hits", 0) for v in snap.values()) == hits
    assert sum(v.get("misses", 0) for v in snap.values()) == misses
    assert hits + misses == 8 * 200
    assert sum(v.get("bytes", 0) for v in snap.values()) == cache.bytes_built
    if cache_kind == "prepared":
        assert cache.bytes_cached <= 64 * 40
