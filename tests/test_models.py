"""Per-architecture smoke tests (reduced configs, CPU): one train step +
prefill/decode consistency + shape/NaN assertions (the assignment's
required smoke contract), for ALL 10 archs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (
    count_params,
    decode_step,
    forward_hidden,
    init_decode_state,
    init_params,
    prefill,
    train_loss,
)


def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "audio_stub":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_train_step(arch, rng):
    """Reduced config: forward + loss + one grad step, no NaNs, shapes OK."""
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(lambda p: train_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    assert 3.0 < float(loss) < 12.0            # ≈ ln(vocab) at init
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    hidden = forward_hidden(cfg, params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_prefill_decode_consistency(arch, rng):
    """Prefill logits at position S−1 ≈ decode-step logits after feeding
    S−1 tokens — the serving path computes the same function as training."""
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")   # tight compare
    if cfg.n_experts:
        # capacity-based token dropping legitimately differs between a
        # full-sequence prefill and a 1-token decode; remove drops so the
        # comparison tests the MATH equivalence of the two paths
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(cfg, jax.random.key(1))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    state = init_decode_state(cfg, b, 64, cache_dtype=jnp.float32)
    logits_pre, state2 = prefill(cfg, params, state, batch)
    # feed the same prefix token-by-token through decode_step
    state_d = init_decode_state(cfg, b, 64, cache_dtype=jnp.float32)
    if cfg.encoder_layers:
        # decode needs the cross-KV from a prefill; use a 1-token prefill
        _, state_d = prefill(cfg, params, state_d,
                             {**batch, "tokens": batch["tokens"][:, :1]})
        start = 1
    else:
        start = 0
        if cfg.frontend == "vision_stub":
            pytest.skip("stepwise decode from scratch undefined with patch stub")
    logits_d = None
    for i in range(start, s):
        logits_d, state_d = decode_step(cfg, params, state_d,
                                        batch["tokens"][:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_pre),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_published():
    expected = {
        "qwen2_1_5b": (1.4e9, 1.7e9),
        "gemma3_12b": (11e9, 13e9),
        "tinyllama_1_1b": (1.0e9, 1.2e9),
        "gemma_2b": (2.0e9, 2.7e9),
        "rwkv6_7b": (7.0e9, 8.0e9),
        "whisper_medium": (0.6e9, 0.9e9),
        "recurrentgemma_9b": (8.5e9, 10e9),
        "qwen3_moe_235b": (230e9, 240e9),
        "arctic_480b": (460e9, 490e9),
        "internvl2_1b": (0.4e9, 0.9e9),   # backbone only (ViT stubbed)
    }
    for arch, (lo, hi) in expected.items():
        n = count_params(configs.get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_live_cells_enumeration():
    cells = configs.live_cells()
    assert len(cells) == 33
    assert ("rwkv6_7b", "long_500k") in cells
    assert ("gemma3_12b", "long_500k") in cells
    assert ("recurrentgemma_9b", "long_500k") in cells
    assert ("qwen2_1_5b", "long_500k") not in cells


def test_loss_chunking_invariance(rng):
    """Chunked CE == unchunked CE (the memory optimisation is exact)."""
    cfg = configs.get_smoke_config("tinyllama_1_1b")
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng, 2, 32)
    l_small = train_loss(dataclasses.replace(cfg, loss_chunk=8), params, batch)
    l_big = train_loss(dataclasses.replace(cfg, loss_chunk=512), params, batch)
    l_unrolled = train_loss(
        dataclasses.replace(cfg, loss_chunk=8, unroll_loss=True), params, batch)
    np.testing.assert_allclose(float(l_small), float(l_big), rtol=2e-5)
    np.testing.assert_allclose(float(l_small), float(l_unrolled), rtol=2e-5)


def test_scan_vs_unrolled_stack(rng):
    """scan_layers=False is numerically identical to the scan form."""
    cfg = configs.get_smoke_config("gemma3_12b")
    cfg32 = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_params(cfg32, jax.random.key(0))
    batch = _batch(cfg32, rng, 2, 16)
    h_scan = forward_hidden(cfg32, params, batch)
    h_unroll = forward_hidden(
        dataclasses.replace(cfg32, scan_layers=False), params, batch)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_unroll),
                               atol=1e-5, rtol=1e-5)


def test_label_masking(rng):
    cfg = configs.get_smoke_config("qwen2_1_5b")
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng, 2, 32)
    full = train_loss(cfg, params, batch)
    masked_labels = batch["labels"].at[:, 16:].set(-1)
    half = train_loss(cfg, params, {**batch, "labels": masked_labels})
    assert np.isfinite(float(half))
    assert abs(float(half) - float(full)) > 1e-6   # actually different rows
