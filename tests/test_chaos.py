"""Chaos suite for the fault plane (DESIGN.md §3.7).

Every probabilistic scenario derives from the seeded, order-independent
:func:`repro.core.chaos.chaos_roll`, so a failure here replays exactly.
CI sweeps ``CHAOS_SEED`` (0, 1, 2); locally any seed must pass.
"""
import os
import threading

import pytest

import repro.tabular  # noqa: F401 — registers estimators
from repro.core import (
    Estimator,
    ExecutorFailure,
    GridBuilder,
    SearchSpec,
    SearchWAL,
    Session,
    TrainedModel,
    enumerate_tasks,
    register_estimator,
    unregister_estimator,
)
from repro.core.chaos import (
    ActiveChaos,
    ChaosTaskError,
    FaultPlan,
    chaos_roll,
    corrupt_json,
    tear_wal_tail,
)
from repro.core.cost_model import CostModel
from repro.core.data_format import PreparedDataCache
from repro.core.evaluation import EvalPlan
from repro.core.executor import LocalExecutorPool, MeshSliceExecutorPool
from repro.core.fault import RetryLedger, WALRecord
from repro.core.fusion import FusedBatch, fuse_tasks
from repro.core.interface import RungTask
from repro.core.scheduler import schedule
from repro.serve.search_service import SearchService

SEED = int(os.environ.get("CHAOS_SEED", "0"))

_NOSLEEP = lambda s: None  # noqa: E731 — retries/backoff pay nothing in tests


class _StubModel(TrainedModel):
    def predict_proba(self, x):
        import numpy as np
        return np.full((x.shape[0],), 0.5, dtype=np.float32)


class _FastEstimator(Estimator):
    name = "chaosfast"
    data_format = "dense_rows"

    def train(self, data, params):
        return _StubModel()

    def train_batched(self, data, configs, *, cache=None):
        return [_StubModel() for _ in configs]

    def fuse_signature(self, params):
        return ()


@pytest.fixture
def fast_estimator():
    register_estimator(_FastEstimator)
    yield _FastEstimator
    unregister_estimator("chaosfast")


def _tasks(n, estimator="chaosfast"):
    return enumerate_tasks(
        [GridBuilder(estimator).add_grid("i", list(range(n))).build()])


# ---------------------------------------------------------------------------
# The deterministic coin and plan-level determinism
# ---------------------------------------------------------------------------

def test_chaos_roll_is_deterministic_and_uniform():
    assert chaos_roll(SEED, 7, 1) == chaos_roll(SEED, 7, 1)
    assert chaos_roll(SEED, 7, 1) != chaos_roll(SEED, 7, 2)
    assert chaos_roll(SEED, 7, 1) != chaos_roll(SEED + 1, 7, 1)
    draws = [chaos_roll(SEED, t, a) for t in range(50) for a in range(1, 4)]
    assert all(0.0 <= d < 1.0 for d in draws)
    # roughly uniform: a pathological hash would cluster
    assert 0.2 < sum(draws) / len(draws) < 0.8


def test_fault_plan_decisions_independent_of_interleaving(higgs_small,
                                                          fast_estimator):
    """Two runs of the same plan on a 3-thread pool inject train faults
    into the SAME tasks — thread scheduling must not change decisions."""
    train, _ = higgs_small

    def run_once():
        chaos = FaultPlan(seed=SEED, task_failure_rate=0.4).build(_NOSLEEP)
        pool = LocalExecutorPool(3, failure_hook=chaos.hook,
                                 max_task_retries=3, retry_backoff=0.0)
        list(pool.submit(schedule(_tasks(12), 3, policy="dynamic"), train))
        return sorted((e[2], e[3]) for e in chaos.events if e[0] == "fault")

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
# Bounded retry: recovery and exhaustion (tentpole i)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_injected_failure_recovers_within_retry_budget(higgs_small, kind,
                                                       fast_estimator):
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({2}),
                      max_task_faults=2).build(_NOSLEEP)
    if kind == "local":
        pool = LocalExecutorPool(2, failure_hook=chaos.hook,
                                 max_task_retries=3, retry_backoff=0.0)
    else:
        pool = MeshSliceExecutorPool(
            slices=["s0", "s1"], failure_hook=chaos.hook,
            max_task_retries=3, retry_backoff=0.0)
    tasks = _tasks(6)
    results = list(pool.submit(schedule(tasks, 2, policy="dynamic"), train))
    assert sorted(r.task.task_id for r in results) == list(range(6))
    assert all(r.ok for r in results)
    by_id = {r.task.task_id: r for r in results}
    assert by_id[2].attempts == 3          # two injected faults + success
    assert all(by_id[i].attempts == 1 for i in range(6) if i != 2)
    assert all(pool.wal.is_done(t.task_id) for t in tasks)


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_retry_exhaustion_is_terminal(higgs_small, kind, fast_estimator):
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({1}),
                      max_task_faults=50).build(_NOSLEEP)
    if kind == "local":
        pool = LocalExecutorPool(2, failure_hook=chaos.hook,
                                 max_task_retries=2, retry_backoff=0.0)
    else:
        pool = MeshSliceExecutorPool(
            slices=["s0", "s1"], failure_hook=chaos.hook,
            max_task_retries=2, retry_backoff=0.0)
    results = list(pool.submit(schedule(_tasks(4), 2, policy="dynamic"),
                               train))
    assert sorted(r.task.task_id for r in results) == list(range(4))
    errs = [r for r in results if not r.ok]
    assert len(errs) == 1 and errs[0].task.task_id == 1
    assert errs[0].attempts == 3           # 1 initial + 2 retries, all burned
    assert "chaos" in errs[0].error
    assert not pool.wal.is_done(1)         # failures stay out of the WAL


def test_retry_backoff_is_capped_exponential():
    slept = []
    ledger = RetryLedger(max_task_retries=40, retry_backoff=0.05,
                         sleep=slept.append)
    for _ in range(12):
        assert ledger.should_retry(9)
        ledger.wait(9)
    assert slept[:4] == [0.05, 0.1, 0.2, 0.4]
    assert max(slept) == RetryLedger.BACKOFF_CAP
    assert slept == sorted(slept)          # monotone up to the cap


# ---------------------------------------------------------------------------
# Poison-task quarantine (tentpole i)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_poison_task_quarantined_within_threshold(higgs_small, kind,
                                                  fast_estimator):
    """A task that kills every executor that claims it is quarantined after
    at most poison_threshold deaths; every other task still completes."""
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED,
                      poison_tasks=frozenset({3})).build(_NOSLEEP)
    if kind == "local":
        pool = LocalExecutorPool(4, failure_hook=chaos.hook,
                                 poison_threshold=2, retry_backoff=0.0)
    else:
        pool = MeshSliceExecutorPool(
            slices=[f"s{i}" for i in range(4)], failure_hook=chaos.hook,
            poison_threshold=2, retry_backoff=0.0)
    results = list(pool.submit(schedule(_tasks(8), 4, policy="dynamic"),
                               train))
    assert sorted(r.task.task_id for r in results) == list(range(8))
    poisoned = [r for r in results if r.task.task_id == 3]
    assert len(poisoned) == 1 and poisoned[0].quarantined
    assert not poisoned[0].ok and "quarantined" in poisoned[0].error
    assert chaos.n_poison_kills <= 2       # quarantine bounded the damage
    assert all(r.ok for r in results if r.task.task_id != 3)


def test_scheduled_executor_death_requeues_on_survivors(higgs_small,
                                                        fast_estimator):
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED,
                      executor_deaths=((0, 2),)).build(_NOSLEEP)
    pool = LocalExecutorPool(3, failure_hook=chaos.hook, retry_backoff=0.0)
    results = list(pool.submit(schedule(_tasks(9), 3, policy="dynamic"),
                               train))
    assert chaos.n_deaths == 1
    assert pool.dead_executors == {0}
    assert sorted(r.task.task_id for r in results) == list(range(9))
    assert all(r.ok for r in results)


# ---------------------------------------------------------------------------
# Fused-batch bisection: good members are salvaged (tentpole i)
# ---------------------------------------------------------------------------

def test_fused_batch_bisection_salvages_good_members(higgs_small):
    """A batch whose fused train raises is bisected down to the culprit:
    good members surface ok, only the poison config carries the error."""
    train, _ = higgs_small

    class _FlakyBatch(Estimator):
        name = "flakybatch"
        data_format = "dense_rows"

        def train(self, data, params):
            if params.get("i") == 1:
                raise ChaosTaskError("poison config")
            return _StubModel()

        def train_batched(self, data, configs, *, cache=None):
            if any(p.get("i") == 1 for p in configs):
                raise ChaosTaskError("poison config in batch")
            return [_StubModel() for _ in configs]

        def fuse_signature(self, params):
            return ()

    register_estimator(_FlakyBatch)
    try:
        tasks = [t.with_cost(1.0) for t in _tasks(4, estimator="flakybatch")]
        units = fuse_tasks(tasks, max_fuse=4)
        assert len(units) == 1 and isinstance(units[0], FusedBatch)
        pool = LocalExecutorPool(1, retry_backoff=0.0)
        results = list(pool.submit(schedule(units, 1, policy="dynamic"),
                                   train))
        assert sorted(r.task.task_id for r in results) == list(range(4))
        bad = [r for r in results if not r.ok]
        assert [r.task.task_id for r in bad] == [1]
        assert all(r.ok for r in results if r.task.task_id != 1)
        assert all(pool.wal.is_done(i) for i in (0, 2, 3))
        assert not pool.wal.is_done(1)
    finally:
        unregister_estimator("flakybatch")


def test_fused_member_retries_solo_after_injected_batch_failure(
        higgs_small, fast_estimator):
    """A chaos hook failing a fused unit burns ONE attempt per member, and
    the members re-queue solo — the whole batch is not retrained."""
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({0, 1, 2, 3}),
                      max_task_faults=1).build(_NOSLEEP)
    tasks = [t.with_cost(1.0) for t in _tasks(4)]
    units = fuse_tasks(tasks, max_fuse=4)
    assert len(units) == 1 and isinstance(units[0], FusedBatch)
    pool = LocalExecutorPool(2, failure_hook=chaos.hook,
                             max_task_retries=1, retry_backoff=0.0)
    results = list(pool.submit(schedule(units, 2, policy="dynamic"), train))
    assert sorted(r.task.task_id for r in results) == list(range(4))
    assert all(r.ok for r in results)
    assert all(r.attempts == 2 for r in results)
    # the solo re-runs rolled their own (per-task) chaos attempts
    assert all(chaos.faults_for(i) == 1 for i in range(4))


# ---------------------------------------------------------------------------
# Deadlines: soft (speculation) and hard (abandon-and-requeue) (tentpole ii)
# ---------------------------------------------------------------------------

def test_deadline_factor_drives_speculation(higgs_small, fast_estimator):
    """deadline_factor rides the speculation path: an overdue task is
    duplicated on an idle executor and the first completion wins."""
    train, _ = higgs_small
    hangs = {4}
    lock = threading.Lock()

    def hook(eid, task):
        with lock:
            first = task.task_id in hangs
            hangs.discard(task.task_id)
        if first:
            import time as _t
            _t.sleep(0.8)

    pool = LocalExecutorPool(2, failure_hook=hook, deadline_factor=3.0)
    tasks = [t.with_cost(0.01) for t in _tasks(6)]
    results = list(pool.submit(schedule(tasks, 2, policy="dynamic"), train))
    # first-completion-wins: exactly one result per config, duplicates dedup
    assert sorted(r.task.task_id for r in results) == list(range(6))
    assert all(r.ok for r in results)


def test_hard_timeout_abandons_and_requeues(higgs_small, fast_estimator):
    """First attempt hangs past the hard deadline: the unit is abandoned
    (the overrun feeds the observer as a censored timed_out observation)
    and the retry completes; the hung worker never blocks the stream."""
    train, _ = higgs_small
    hangs = {2}
    lock = threading.Lock()
    observed = []

    def hook(eid, task):
        with lock:
            first = task.task_id in hangs
            hangs.discard(task.task_id)
        if first:
            import time as _t
            _t.sleep(3.0)

    pool = LocalExecutorPool(2, failure_hook=hook, task_timeout_seconds=0.3,
                             max_task_retries=1, retry_backoff=0.0,
                             on_result=observed.append)
    results = list(pool.submit(schedule(_tasks(5), 2, policy="dynamic"),
                               train))
    assert sorted(r.task.task_id for r in results) == list(range(5))
    assert all(r.ok for r in results)
    # the censored overrun reached the observer, flagged timed_out
    timeouts = [r for r in observed if r.timed_out]
    assert timeouts and timeouts[0].task.task_id == 2
    assert timeouts[0].train_seconds >= 0.3


def test_hard_timeout_exhaustion_is_terminal_timed_out(higgs_small,
                                                       fast_estimator):
    """A task that hangs on every attempt surfaces as a terminal timed_out
    error result — the stream finishes despite the hung workers."""
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, hang_tasks={1: 5.0}).build()
    pool = LocalExecutorPool(2, failure_hook=chaos.hook,
                             task_timeout_seconds=0.3, max_task_retries=1,
                             retry_backoff=0.0)
    results = list(pool.submit(schedule(_tasks(4), 2, policy="dynamic"),
                               train))
    assert sorted(r.task.task_id for r in results) == list(range(4))
    bad = [r for r in results if not r.ok]
    assert len(bad) == 1 and bad[0].task.task_id == 1
    assert bad[0].timed_out and "deadline" in bad[0].error
    assert all(r.ok for r in results if r.task.task_id != 1)


def test_timed_out_overrun_feeds_cost_model():
    """CostModel.observe_result treats a timed_out failure as a censored
    runtime observation — the estimate that missed stops being trusted."""
    from repro.core.interface import TaskResult, TrainTask
    cm = CostModel(None)
    t = TrainTask(task_id=0, estimator="gbdt", params={"round": 5})
    cm.observe_result(TaskResult(task=t, model=None, train_seconds=2.5,
                                 executor_id=0, error="deadline",
                                 timed_out=True), n_rows=1000)
    assert cm.n_observed == 1
    # a plain failure still contributes nothing
    cm.observe_result(TaskResult(task=t, model=None, train_seconds=0.0,
                                 executor_id=0, error="boom"), n_rows=1000)
    assert cm.n_observed == 1


# ---------------------------------------------------------------------------
# Storage faults: torn WAL tail (satellite 1), corrupt cost model (satellite 2)
# ---------------------------------------------------------------------------

def test_torn_wal_tail_skips_last_record_with_warning(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = SearchWAL(path)
    for i in range(3):
        wal.record(WALRecord(task_id=i, key=f"k{i}", seconds=1.0,
                             executor_id=0))
    assert tear_wal_tail(path) > 0
    with pytest.warns(RuntimeWarning, match="corrupt record"):
        reopened = SearchWAL(path)
    # the torn record re-runs; the committed prefix survives
    assert sorted(reopened.completed()) == [0, 1]


def test_torn_resume_line_skipped_with_warning(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = SearchWAL(path)
    wal.record(WALRecord(task_id=0, key="k0", seconds=1.0, executor_id=0))
    with open(path, "a") as f:  # torn mid-append resume record
        f.write('{"kind": "resume", "task_id": 1, "state": {"bud')
    with pytest.warns(RuntimeWarning, match="corrupt record"):
        reopened = SearchWAL(path)
    assert sorted(reopened.completed()) == [0]
    assert reopened.resume_state(1) is None


def test_wal_garbage_line_mid_file_skipped(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    wal = SearchWAL(path)
    wal.record(WALRecord(task_id=0, key="k0", seconds=1.0, executor_id=0))
    with open(path, "a") as f:  # garbage line, then a valid record after it
        import dataclasses as _dc
        import json as _json
        f.write("not json at all\n")
        f.write(_json.dumps(_dc.asdict(
            WALRecord(task_id=2, key="k2", seconds=1.0, executor_id=1))) + "\n")
    with pytest.warns(RuntimeWarning, match="corrupt record"):
        reopened = SearchWAL(path)
    assert sorted(reopened.completed()) == [0, 2]


def test_corrupt_cost_model_starts_cold_and_preserves_file(tmp_path):
    path = str(tmp_path / "model.cost.json")
    cm = CostModel(path)
    from repro.core.interface import TrainTask
    for _ in range(3):
        cm.observe(TrainTask(task_id=0, estimator="gbdt",
                             params={"round": 5}), 1.0, 1000)
    cm.save()
    corrupt_json(path)
    with pytest.warns(RuntimeWarning, match="corrupt"):
        reloaded = CostModel.open(path)
    assert reloaded.n_observed == 0        # cold start, not a crash
    assert os.path.exists(path + ".corrupt")
    # and the cold model can save over the bad path again
    reloaded.observe(TrainTask(task_id=0, estimator="gbdt",
                               params={"round": 5}), 1.0, 1000)
    reloaded.save()
    assert CostModel.open(path).n_observed == 1


def test_prepared_cache_build_failure_does_not_poison_key():
    cache = PreparedDataCache()
    calls = []

    def flaky_builder():
        calls.append(1)
        if len(calls) == 1:
            raise ChaosTaskError("injected conversion failure")
        return "prepared"

    with pytest.raises(ChaosTaskError):
        cache.get("k", flaky_builder)
    value, _, built = cache.get("k", flaky_builder)   # retry rebuilds
    assert value == "prepared" and built and len(calls) == 2


# ---------------------------------------------------------------------------
# Service chaos: retries, worker deaths and quarantine on shared workers
# ---------------------------------------------------------------------------

def test_service_retries_and_quarantines(higgs_small, fast_estimator):
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({1}),
                      max_task_faults=2,
                      poison_tasks=frozenset({4})).build(_NOSLEEP)
    svc = SearchService(n_executors=3, failure_hook=chaos.hook,
                        sleep=_NOSLEEP)
    try:
        spec = SearchSpec(
            spaces=[GridBuilder("chaosfast").add_grid(
                "i", list(range(6))).build()],
            n_executors=3, policy="dynamic",
            max_task_retries=3, retry_backoff=0.0, poison_threshold=2)
        handle = svc.submit_search(spec, train, tenant="chaos")
        results = list(handle.results())
        assert sorted(r.task.task_id for r in results) == list(range(6))
        by_id = {r.task.task_id: r for r in results}
        assert by_id[1].ok and by_id[1].attempts == 3
        assert by_id[4].quarantined and not by_id[4].ok
        assert chaos.n_poison_kills <= 2
        assert all(by_id[i].ok for i in range(6) if i != 4)
    finally:
        svc.close()


def test_session_end_to_end_chaos_stats(higgs_small, fast_estimator):
    """Session + LocalExecutorPool under chaos: spec-level retry knobs flow
    into the pool and the run's SearchStats account for the damage."""
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({0}),
                      max_task_faults=1).build(_NOSLEEP)
    spec = SearchSpec(
        spaces=[GridBuilder("chaosfast").add_grid(
            "i", list(range(5))).build()],
        n_executors=2, policy="dynamic",
        max_task_retries=2, retry_backoff=0.0,
        pool_options={"failure_hook": chaos.hook})
    session = Session(spec)
    results = list(session.results(train))
    assert sorted(r.task.task_id for r in results) == list(range(5))
    assert all(r.ok for r in results)
    assert session.stats.n_retries == 1
    assert session.stats.n_quarantined == 0
    assert session.stats.n_failures == 0


# ---------------------------------------------------------------------------
# Satellite 4: eval failures degrade (score=None), never retry, never
# double-journal — on the solo, fused-member and rung paths
# ---------------------------------------------------------------------------

def _wal_journal_counts(path):
    import json as _json
    counts = {}
    with open(path) as f:
        for line in f:
            obj = _json.loads(line)
            if obj.get("kind") != "resume":
                tid = obj["task_id"]
                counts[tid] = counts.get(tid, 0) + 1
    return counts


class _EvalBoomModel(TrainedModel):
    def predict_proba(self, x):
        raise RuntimeError("scoring exploded")

    def predict_proba_jax(self, x, *, cache=None):
        raise RuntimeError("scoring exploded")

    @classmethod
    def predict_proba_batched(cls, models, x, *, cache=None):
        raise RuntimeError("scoring exploded")


class _EvalBoomEstimator(Estimator):
    name = "evalboom"
    data_format = "dense_rows"
    budget_param = "round"      # lets RungTasks ride the resumable path

    def train(self, data, params):
        return _EvalBoomModel()

    def train_batched(self, data, configs, *, cache=None):
        return [_EvalBoomModel() for _ in configs]

    def fuse_signature(self, params):
        return ()


@pytest.fixture
def evalboom():
    register_estimator(_EvalBoomEstimator)
    yield _EvalBoomEstimator
    unregister_estimator("evalboom")


def test_eval_failure_solo_degrades_under_retry(higgs_small, tmp_path,
                                                evalboom):
    train, valid = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    pool = LocalExecutorPool(2, wal=SearchWAL(wal_path),
                             max_task_retries=3, retry_backoff=0.0)
    tasks = _tasks(3, estimator="evalboom")
    results = list(pool.submit(schedule(tasks, 2, policy="dynamic"), train,
                               validate=EvalPlan(valid, "auc")))
    assert sorted(r.task.task_id for r in results) == list(range(3))
    # trained models survive their broken evaluation: ok, score=None, and
    # crucially NO retry was burned on the eval failure
    assert all(r.ok and r.score is None and r.model is not None
               for r in results)
    assert all(r.attempts == 1 for r in results)
    assert all(c == 1 for c in _wal_journal_counts(wal_path).values())


def test_eval_failure_fused_members_degrade_under_retry(higgs_small,
                                                        tmp_path, evalboom):
    train, valid = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    tasks = [t.with_cost(1.0) for t in _tasks(4, estimator="evalboom")]
    units = fuse_tasks(tasks, max_fuse=4)
    assert len(units) == 1 and isinstance(units[0], FusedBatch)
    pool = LocalExecutorPool(1, wal=SearchWAL(wal_path),
                             max_task_retries=3, retry_backoff=0.0)
    results = list(pool.submit(schedule(units, 1, policy="dynamic"), train,
                               validate=EvalPlan(valid, "auc")))
    assert sorted(r.task.task_id for r in results) == list(range(4))
    assert all(r.ok and r.score is None and r.model is not None
               for r in results)
    assert all(r.attempts == 1 for r in results)
    assert all(c == 1 for c in _wal_journal_counts(wal_path).values())


def test_eval_failure_on_retried_task_still_journals_once(higgs_small,
                                                          tmp_path,
                                                          evalboom):
    """A task that fails training once THEN trains but can't score: the
    retry happens for the train failure only, the final ok result with
    score=None journals exactly once."""
    train, valid = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    chaos = FaultPlan(seed=SEED, fail_tasks=frozenset({0}),
                      max_task_faults=1).build(_NOSLEEP)
    pool = LocalExecutorPool(2, wal=SearchWAL(wal_path),
                             failure_hook=chaos.hook,
                             max_task_retries=2, retry_backoff=0.0)
    results = list(pool.submit(
        schedule(_tasks(3, estimator="evalboom"), 2, policy="dynamic"),
        train, validate=EvalPlan(valid, "auc")))
    by_id = {r.task.task_id: r for r in results}
    assert by_id[0].ok and by_id[0].score is None and by_id[0].attempts == 2
    assert all(c == 1 for c in _wal_journal_counts(wal_path).values())


def test_eval_failure_rung_task_degrades(higgs_small, tmp_path, evalboom):
    """The rung (resumable, §3.6) path shares the same degradation: a rung
    whose predictor raises still yields its trained model, score=None,
    without burning a retry or double-journalling."""
    train, valid = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    rung = RungTask(task_id=0, estimator="evalboom",
                    params={"round": 3}, cost=1.0,
                    config_id=0, rung=0, budget=3, prev_budget=0,
                    budget_param="round")
    pool = LocalExecutorPool(1, wal=SearchWAL(wal_path),
                             max_task_retries=2, retry_backoff=0.0)
    results = list(pool.submit(
        schedule([rung], 1, policy="dynamic"), train,
        validate=EvalPlan(valid, "auc")))
    [res] = results
    assert res.ok and res.model is not None and res.score is None
    assert res.attempts == 1
    assert _wal_journal_counts(wal_path) == {0: 1}


# ---------------------------------------------------------------------------
# Quarantine counters surface in stats
# ---------------------------------------------------------------------------

def test_session_counts_quarantined_tasks(higgs_small, fast_estimator):
    train, _ = higgs_small
    chaos = FaultPlan(seed=SEED, poison_tasks=frozenset({2})).build(_NOSLEEP)
    spec = SearchSpec(
        spaces=[GridBuilder("chaosfast").add_grid(
            "i", list(range(5))).build()],
        n_executors=4, policy="dynamic",
        poison_threshold=2, retry_backoff=0.0,
        pool_options={"failure_hook": chaos.hook})
    session = Session(spec)
    results = list(session.results(train))
    assert sorted(r.task.task_id for r in results) == list(range(5))
    assert session.stats.n_quarantined == 1
    assert session.stats.n_failures == 1
