"""register_estimator accepts classes, factories, and instances (paper §III-B:
plugging in a new ML implementation is registry glue, nothing more)."""
import numpy as np
import pytest

from repro.core import (
    Estimator,
    TrainedModel,
    get_estimator,
    register_estimator,
    unregister_estimator,
)


class _Model(TrainedModel):
    def predict_proba(self, x):
        return np.zeros(x.shape[0], dtype=np.float32)


def _mk_estimator_cls(cls_name):
    class _Est(Estimator):
        name = cls_name

        def train(self, data, params):
            return _Model()

    _Est.__name__ = cls_name
    return _Est


@pytest.fixture
def clean_registry():
    names = []
    yield names
    for n in names:
        unregister_estimator(n)


def test_register_class_instantiates_fresh(clean_registry):
    cls = _mk_estimator_cls("reg_cls")
    assert register_estimator(cls) is cls       # decorator-transparent
    clean_registry.append("reg_cls")
    a, b = get_estimator("reg_cls"), get_estimator("reg_cls")
    assert isinstance(a, cls) and isinstance(b, cls)
    assert a is not b                           # new instance per lookup


def test_register_factory_called_per_lookup(clean_registry):
    cls = _mk_estimator_cls("reg_factory")
    calls = []

    def factory():
        calls.append(1)
        return cls()

    register_estimator(factory)
    clean_registry.append("reg_factory")
    get_estimator("reg_factory")
    get_estimator("reg_factory")
    assert len(calls) == 3                      # 1 probe + 2 lookups


def test_register_instance_returns_same_object(clean_registry):
    inst = _mk_estimator_cls("reg_inst")()
    assert register_estimator(inst) is inst
    clean_registry.append("reg_inst")
    assert get_estimator("reg_inst") is inst
    assert get_estimator("reg_inst") is inst


def test_register_rejects_bad_inputs(clean_registry):
    with pytest.raises(TypeError):
        register_estimator(object())            # not class/factory/instance
    with pytest.raises(TypeError):
        register_estimator(dict)                # class, but not an Estimator
    with pytest.raises(TypeError):
        register_estimator(lambda: object())    # factory of non-Estimator

    class NoName(Estimator):
        def train(self, data, params):
            return _Model()

    with pytest.raises(ValueError):
        register_estimator(NoName)              # empty .name

    cls = _mk_estimator_cls("reg_dup")
    register_estimator(cls)
    clean_registry.append("reg_dup")
    with pytest.raises(ValueError):
        register_estimator(cls)                 # duplicate name


def test_unregister_allows_reregistration(clean_registry):
    cls = _mk_estimator_cls("reg_cycle")
    register_estimator(cls)
    unregister_estimator("reg_cycle")
    register_estimator(cls)                     # no duplicate error
    clean_registry.append("reg_cycle")
    assert isinstance(get_estimator("reg_cycle"), cls)
