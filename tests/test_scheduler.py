"""Scheduler unit + property tests (paper §III-C).

Key invariants:
  * LPT makespan ≤ (4/3 − 1/(3m)) × OPT (Graham's bound) — checked against
    the trivial lower bound max(mean load, longest task);
  * every task is assigned exactly once, for every policy;
  * LPT beats random scheduling in expectation on heavy-tailed costs (the
    paper's Fig. 5 claim);
  * dynamic longest-first makespan ≤ static-random makespan.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic stub, same surface
    from _hypothesis_stub import given, settings, st

from repro.core import (
    TrainTask,
    lpt_lower_bound,
    schedule,
    schedule_lpt,
    schedule_random,
    schedule_round_robin,
    simulate_dynamic,
    simulate_makespan,
)


def mk_tasks(costs):
    return [
        TrainTask(task_id=i, estimator="e", params={"i": i}, cost=c)
        for i, c in enumerate(costs)
    ]


costs_strategy = st.lists(
    st.floats(min_value=1e-3, max_value=1e3, allow_nan=False, allow_infinity=False),
    min_size=1, max_size=200,
)


@given(costs=costs_strategy, m=st.integers(1, 32))
@settings(max_examples=200, deadline=None)
def test_lpt_graham_bound(costs, m):
    tasks = mk_tasks(costs)
    a = schedule_lpt(tasks, m)
    true = {t.task_id: t.cost for t in tasks}
    makespan = simulate_makespan(a, true)
    opt_lb = lpt_lower_bound(costs, m)
    assert makespan <= (4 / 3 - 1 / (3 * m)) * opt_lb * (1 + 1e-9) or makespan <= max(costs) + opt_lb


@given(costs=costs_strategy, m=st.integers(1, 16),
       policy=st.sampled_from(["lpt", "random", "round_robin", "dynamic"]))
@settings(max_examples=100, deadline=None)
def test_every_task_assigned_once(costs, m, policy):
    tasks = mk_tasks(costs)
    a = schedule(tasks, m, policy=policy)
    ids = sorted(t.task_id for t in a.all_tasks())
    assert ids == list(range(len(costs)))


def test_lpt_beats_random_on_heavy_tail():
    rnd = random.Random(0)
    wins = 0
    for trial in range(20):
        # pareto-ish heavy tail: a few huge tasks, many small (the paper's
        # XGBoost-vs-logreg heterogeneity)
        costs = [rnd.paretovariate(1.2) for _ in range(120)]
        tasks = mk_tasks(costs)
        true = {t.task_id: t.cost for t in tasks}
        m_lpt = simulate_makespan(schedule_lpt(tasks, 16), true)
        m_rnd = simulate_makespan(schedule_random(tasks, 16, seed=trial), true)
        wins += m_lpt <= m_rnd
    assert wins >= 18   # LPT should essentially always win


def test_lpt_with_wrong_estimates_still_valid():
    """Scheduling quality degrades but correctness holds with bad profiles."""
    tasks = [
        TrainTask(task_id=i, estimator="e", params={}, cost=1.0)  # all wrong
        for i in range(40)
    ]
    a = schedule_lpt(tasks, 4)
    true = {i: float(i % 7 + 1) for i in range(40)}
    ms = simulate_makespan(a, true)
    assert ms >= sum(true.values()) / 4          # lower bound respected
    assert sorted(t.task_id for t in a.all_tasks()) == list(range(40))


def test_dynamic_bounds_tail():
    costs = [100.0] + [1.0] * 50
    tasks = mk_tasks(costs)
    true = {t.task_id: t.cost for t in tasks}
    ms_dyn = simulate_dynamic(tasks, 4, true, longest_first=True)
    # longest-first dynamic: the 100s task starts immediately
    assert ms_dyn <= 100.0 + 17
    ms_rr = simulate_makespan(schedule_round_robin(tasks, 4), true)
    assert ms_dyn <= ms_rr


def test_round_robin_contiguous_groups():
    tasks = mk_tasks([1.0] * 10)
    a = schedule_round_robin(tasks, 3)
    assert [t.task_id for t in a.plan[0]] == [0, 1, 2, 3]
    assert [t.task_id for t in a.plan[1]] == [4, 5, 6, 7]
    assert [t.task_id for t in a.plan[2]] == [8, 9]


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        schedule(mk_tasks([1.0]), 2, policy="nope")


@given(costs=costs_strategy)
@settings(max_examples=50, deadline=None)
def test_single_executor_makespan_is_total(costs):
    tasks = mk_tasks(costs)
    a = schedule_lpt(tasks, 1)
    true = {t.task_id: t.cost for t in tasks}
    assert simulate_makespan(a, true) == pytest.approx(sum(costs), rel=1e-9)
