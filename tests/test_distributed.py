"""Distribution-layer tests. Multi-device cases run in SUBPROCESSES with
``--xla_force_host_platform_device_count`` so the main test process keeps
the single-device view (the smoke-test contract)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

# Multi-device SPMD compiles take minutes each on a CPU host; they run in
# the nightly/heavy CI lane (ci.yml) rather than every tier-1 invocation.
heavy = pytest.mark.skipif(
    os.environ.get("REPRO_HEAVY_TESTS") != "1",
    reason="multi-device subprocess test (minutes of XLA CPU compile); "
           "set REPRO_HEAVY_TESTS=1 to run",
)

from repro import configs
from repro.distributed import sharding as shd
from repro.models import init_params
from repro.train import make_optimizer
from repro.train.train_step import make_train_state_specs, opt_pspecs


def run_subprocess(code: str, devices: int = 8) -> str:
    """Run a python snippet with N fake host devices; returns stdout."""
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
    )
    assert res.returncode == 0, f"subprocess failed:\n{res.stderr[-3000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# sharding rules (no devices needed — pure pspec logic)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_pspecs_cover_every_leaf(arch):
    cfg = configs.get_smoke_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = shd.param_pspecs(shapes, fsdp=True)
    n_leaves = len(jax.tree.leaves(shapes))
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(spec_leaves) == n_leaves
    for leaf, spec in zip(jax.tree.leaves(shapes), spec_leaves):
        assert len(spec) <= len(leaf.shape)
        used = [a for a in spec if a is not None]
        assert len(used) == len(set(used)), f"axis reused in {spec}"


def test_embed_and_ffn_rules():
    shapes = {
        "embed": jax.ShapeDtypeStruct((1024, 64), jnp.float32),
        "blocks": {"b0": {"ffn": {
            "w_gate": jax.ShapeDtypeStruct((2, 64, 256), jnp.float32),
            "w_down": jax.ShapeDtypeStruct((2, 256, 64), jnp.float32),
        }}},
    }
    specs = shd.param_pspecs(shapes, fsdp=False)
    assert specs["embed"] == P("tp", None)
    assert specs["blocks"]["b0"]["ffn"]["w_gate"] == P(None, None, "tp")
    assert specs["blocks"]["b0"]["ffn"]["w_down"] == P(None, "tp", None)


def test_zero1_shards_largest_free_dim():
    shapes = {"w": jax.ShapeDtypeStruct((64, 512), jnp.float32)}
    specs = {"w": P(None, "tp")}
    z = shd.zero1_pspecs(specs, shapes, data_size=16)
    assert z["w"] == P("dp", "tp")
    # not divisible → untouched
    shapes2 = {"w": jax.ShapeDtypeStruct((7, 13), jnp.float32)}
    z2 = shd.zero1_pspecs({"w": P(None, None)}, shapes2, data_size=16)
    assert z2["w"] == P(None, None)


def test_opt_pspecs_adafactor_drops_dims():
    shapes = {"w": jax.ShapeDtypeStruct((64, 512), jnp.float32),
              "b": jax.ShapeDtypeStruct((512,), jnp.float32)}
    p_specs = {"w": P("dp", "tp"), "b": P("tp")}
    o = opt_pspecs("adafactor", p_specs, shapes)
    assert o["w"]["row"] == P("dp")
    assert o["w"]["col"] == P("tp")
    assert o["b"]["v"] == P("tp")


def test_logical_to_mesh_multipod_tuples():
    mapped = shd.logical_to_mesh({"x": P("dp", "tp"), "y": P(("dp", "tp"))},
                                 {"dp": ("pod", "data"), "tp": "model"})
    assert mapped["x"] == P(("pod", "data"), "model")
    assert mapped["y"] == P(("pod", "data", "model"))


def test_state_pspecs_divisibility_fallbacks():
    kv = {"blocks": {"b0": {"kv": {
        "k": jax.ShapeDtypeStruct((2, 1, 3, 64, 16), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((2, 1, 3, 64, 16), jnp.bfloat16),
    }}}}
    # hkv=3 doesn't divide tp=4 → fall back to sequence sharding (64 % 4 == 0)
    specs = shd.state_pspecs(kv, dp_size=1, tp_size=4)
    assert specs["blocks"]["b0"]["kv"]["k"] == P(None, None, None, "tp", None)
    # batch=2 doesn't divide dp=4 → batch unsharded
    specs2 = shd.state_pspecs(kv, dp_size=4, tp_size=4)
    assert specs2["blocks"]["b0"]["kv"]["k"][1] is None


# ---------------------------------------------------------------------------
# multi-device behaviour (subprocesses with 8 fake devices)
# ---------------------------------------------------------------------------

def test_train_state_specs_build():
    cfg = configs.get_smoke_config("qwen3_moe_235b")
    shapes, specs = make_train_state_specs(
        cfg, make_optimizer("adafactor"), fsdp=True, zero1=True, data_size=2
    )
    assert set(specs) == {"step", "params", "opt_state"}
    moe_spec = specs["params"]["blocks"]["b0"]["moe"]["w_gate"]
    assert moe_spec == P(None, "tp", "dp", None)


@heavy
def test_pipeline_parallel_subprocess():
    out = run_subprocess("""
        import jax, jax.numpy as jnp
        from repro import compat
        from repro.launch.mesh import compat_make_mesh
        from repro.distributed.pipeline import pipeline_apply, bubble_fraction
        mesh = compat_make_mesh((4,), ("stage",))
        S, B, D, M = 4, 8, 16, 4
        w = jax.random.normal(jax.random.key(0), (S, D, D), jnp.float32) * 0.3
        x = jax.random.normal(jax.random.key(1), (B, D), jnp.float32)
        fn = lambda p, h: jax.nn.gelu(h @ p["w"])
        with compat.set_mesh(mesh):
            y = pipeline_apply(fn, {"w": w}, x, mesh, n_microbatches=M)
        ref = x
        for s in range(S):
            ref = jax.nn.gelu(ref @ w[s])
        print("ERR", float(jnp.abs(y - ref).max()))
        print("BUBBLE", bubble_fraction(S, M))
    """)
    err = float(out.split("ERR ")[1].split()[0])
    assert err < 1e-5
    assert "BUBBLE 0.42" in out               # (4−1)/(4+4−1) = 3/7


@heavy
def test_int8_compressed_allreduce_subprocess():
    out = run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.launch.mesh import compat_make_mesh
        from repro.distributed.collectives import compressed_psum
        mesh = compat_make_mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.key(0), (8, 64), jnp.float32)
        def f(gs):
            out, res = compressed_psum({"g": gs}, "dp")
            return out["g"], res["g"]
        with compat.set_mesh(mesh):
            mean, resid = compat.shard_map(f, mesh=mesh, in_specs=P("dp"),
                                           out_specs=(P(), P("dp")), check_vma=False)(g)
        true = g.mean(0)
        rel = float(jnp.abs(mean[0] - true).max() / jnp.abs(true).max())
        print("REL", rel)
        # error feedback residual bounded by one quantisation step
        print("RESID", float(jnp.abs(resid).max()))
    """)
    rel = float(out.split("REL ")[1].split()[0])
    assert rel < 0.02
    resid = float(out.split("RESID ")[1].split()[0])
    assert resid < 0.1


@heavy
def test_fsdp_trainer_subprocess():
    """FSDP + ZeRO-1 + int8-DP trainer converges on 2×4 mesh."""
    out = run_subprocess("""
        import jax
        from repro.launch.mesh import compat_make_mesh
        from repro import configs
        from repro.train import Trainer, make_optimizer
        from repro.data.pipeline import make_lm_stream
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        cfg = configs.get_smoke_config("tinyllama_1_1b")
        stream = make_lm_stream(mesh, batch=8, seq_len=32, vocab=cfg.vocab)
        tr = Trainer(cfg, make_optimizer("adamw", lr=3e-3), mesh, stream,
                     fsdp=True, zero1=True)
        m = tr.run(10)
        stream.close()
        print("FIRST", m.history[0]["loss"], "LAST", m.history[-1]["loss"])
    """)
    first = float(out.split("FIRST ")[1].split()[0])
    last = float(out.split("LAST ")[1].split()[0])
    assert last < first                        # learning under FSDP sharding


@heavy
def test_shard_map_int8_dp_mode_subprocess():
    out = run_subprocess("""
        import jax
        from repro.launch.mesh import compat_make_mesh
        from repro import configs
        from repro.train import Trainer, make_optimizer
        from repro.data.pipeline import make_lm_stream
        mesh = compat_make_mesh((4, 2), ("data", "model"))
        cfg = configs.get_smoke_config("qwen2_1_5b")
        stream = make_lm_stream(mesh, batch=8, seq_len=32, vocab=cfg.vocab)
        tr = Trainer(cfg, make_optimizer("adamw", lr=3e-3), mesh, stream,
                     dp_mode="shard_map_int8")
        m = tr.run(8)
        stream.close()
        print("FIRST", m.history[0]["loss"], "LAST", m.history[-1]["loss"])
    """)
    first = float(out.split("FIRST ")[1].split()[0])
    last = float(out.split("LAST ")[1].split()[0])
    assert last < first


@heavy
def test_serve_engine_sharded_subprocess():
    out = run_subprocess("""
        import jax, numpy as np
        from repro import compat
        from repro.launch.mesh import compat_make_mesh
        from repro import configs
        from repro.models import init_params
        from repro.serve import ServeEngine, Request
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        cfg = configs.get_smoke_config("gemma_2b")
        with compat.set_mesh(mesh):
            params = init_params(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, mesh, batch_size=4, max_len=64)
        reqs = [Request(i, np.arange(1, 5 + i, dtype=np.int32), max_new_tokens=4)
                for i in range(4)]
        done = eng.serve(reqs)
        print("TOKENS", sum(len(r.output) for r in done))
    """)
    assert int(out.split("TOKENS ")[1].split()[0]) == 16
