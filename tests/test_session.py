"""Session/SearchSpec/ExecutorBackend behaviour: streaming results, budgets,
WAL resume, and fault-recovery parity across both backend implementations."""
import threading

import pytest

import repro.tabular  # noqa: F401 — registers estimators
from repro.core import (
    Estimator,
    ExecutorBackend,
    ExecutorFailure,
    GridBuilder,
    LocalExecutorPool,
    MeshSliceExecutorPool,
    SamplingProfiler,
    SearchSpec,
    SearchWAL,
    Session,
    TrainedModel,
    enumerate_tasks,
    get_estimator,
    register_estimator,
    schedule,
    unregister_estimator,
)


def small_spaces():
    return [
        GridBuilder("logreg").add_grid("c", [0.05, 0.3]).add_grid("steps", [60]).build(),
        GridBuilder("mlp").add_grid("network", ["16_16"]).add_grid("steps", [60]).build(),
        GridBuilder("gbdt").add_grid("round", [5]).add_grid("max_depth", [3]).build(),
        GridBuilder("forest").add_grid("n_estimators", [5]).add_grid("max_depth", [4]).build(),
    ]


# ---------------------------------------------------------------------------
# SearchSpec: declarative construction + validation
# ---------------------------------------------------------------------------

def test_spec_validates_at_construction():
    sp = GridBuilder("logreg").add_grid("c", [0.1]).build()
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], policy="nope")
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], metric="nope")
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], n_executors=0)
    with pytest.raises(ValueError):
        SearchSpec(spaces=())                      # no spaces, no tuner
    with pytest.raises(ValueError):
        SearchSpec(spaces=[sp], tuner={"no_kind": 1})
    with pytest.raises(TypeError):
        SearchSpec(spaces=[sp], profiler=object())


def test_spec_is_frozen_and_replace_copies():
    sp = GridBuilder("logreg").add_grid("c", [0.1, 0.3]).build()
    spec = SearchSpec(spaces=[sp], n_executors=2)
    with pytest.raises(AttributeError):
        spec.policy = "random"
    spec2 = spec.replace(policy="random", n_executors=4)
    assert spec.policy == "lpt" and spec2.policy == "random"
    assert spec2.spaces == spec.spaces
    assert spec.n_grid_tasks == 2


def test_spec_from_dict_declarative():
    spec = SearchSpec.from_dict({
        "spaces": [{"estimator": "logreg", "grid": {"c": [0.1, 0.3]}},
                   {"estimator": "gbdt", "grid": {"round": [5], "max_depth": [3, 4]}}],
        "n_executors": 3,
        "policy": "dynamic",
        "tuner": {"kind": "random", "n_samples": 3},
        "profiler": {"kind": "sampling", "sampling_rate": 0.05},
        "max_tasks": 2,
    })
    assert spec.n_grid_tasks == 4
    assert spec.spaces[0].estimator == "logreg"
    tuner = spec.build_tuner()
    assert len(tuner.propose()) == 3
    assert spec.build_profiler().sampling_rate == 0.05
    with pytest.raises(ValueError):
        SearchSpec.from_dict({"spaces": [], "bogus_key": 1})


# ---------------------------------------------------------------------------
# Streaming: results arrive incrementally, callbacks see them mid-search
# ---------------------------------------------------------------------------

def test_results_stream_incrementally(higgs_small):
    train, _ = higgs_small
    spec = SearchSpec(spaces=small_spaces(), n_executors=2,
                      profiler=SamplingProfiler(0.05))
    session = Session(spec)
    seen_flags = []
    gen = session.results(train, on_result=lambda r: seen_flags.append(session.finished))
    first = next(gen)                       # one task has completed ...
    assert first.ok
    assert not session.finished             # ... while the search is still live
    rest = list(gen)
    assert session.finished
    assert 1 + len(rest) == 5
    # the callback observed every result, all before the search finished
    assert len(seen_flags) == 5
    assert not any(seen_flags)


def test_multi_model_usable_mid_stream(higgs_small):
    train, valid = higgs_small
    spec = SearchSpec(spaces=small_spaces(), n_executors=2,
                      profiler=SamplingProfiler(0.05))
    session = Session(spec)
    gen = session.results(train)
    next(gen)
    assert len(session.multi_model()) == 1  # partial results are queryable
    list(gen)
    assert session.multi_model().best(valid).score > 0.6


def test_session_refuses_second_run(higgs_small):
    train, _ = higgs_small
    spec = SearchSpec(spaces=small_spaces()[:1], n_executors=1,
                      profiler=SamplingProfiler(0.1))
    session = Session(spec)
    session.search(train)
    with pytest.raises(RuntimeError):
        next(session.results(train))


# ---------------------------------------------------------------------------
# Budgets: early-stop mid-stream
# ---------------------------------------------------------------------------

def test_max_tasks_budget_stops_early(higgs_small):
    train, _ = higgs_small
    spec = SearchSpec(spaces=small_spaces(), n_executors=2,
                      profiler=SamplingProfiler(0.05), max_tasks=2)
    session = Session(spec)
    out = list(session.results(train))
    assert len(out) == 2
    assert session.stop_reason == "max_tasks"


def test_target_metric_budget_stops_on_good_model(higgs_small):
    train, valid = higgs_small
    spaces = [GridBuilder("logreg").add_grid("c", [0.05, 0.1, 0.3, 0.9]).build()]
    spec = SearchSpec(spaces=spaces, n_executors=1,
                      profiler=SamplingProfiler(0.1), target_metric=0.6)
    session = Session(spec)
    out = list(session.results(train, valid))
    assert session.stop_reason == "target_metric"
    assert len(out) < 4                     # stopped before the full grid


# ---------------------------------------------------------------------------
# Resume: a killed search completes without re-running WAL-recorded tasks
# ---------------------------------------------------------------------------

class _CountingModel(TrainedModel):
    def predict_proba(self, x):
        import numpy as np
        return np.full((x.shape[0],), 0.5, dtype=np.float32)


class _CountingEstimator(Estimator):
    name = "counting"
    data_format = "dense_rows"
    trained: list = []                       # class-level: shared across lookups

    def train(self, data, params):
        type(self).trained.append(params["i"])
        return _CountingModel()


@pytest.fixture
def counting_estimator():
    _CountingEstimator.trained = []
    register_estimator(_CountingEstimator)
    yield _CountingEstimator
    unregister_estimator("counting")


def test_resume_completes_without_rerunning(higgs_small, tmp_path, counting_estimator):
    train, _ = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    spaces = [GridBuilder("counting").add_grid("i", list(range(6))).build()]
    # round_robin is cost-blind → no profiling runs to pollute the counts
    spec = SearchSpec(spaces=spaces, n_executors=1, policy="round_robin",
                      wal_path=wal_path, max_tasks=2)
    killed = Session(spec)
    got = list(killed.results(train))
    assert killed.stop_reason == "max_tasks" and len(got) == 2
    journalled_before = len(SearchWAL(wal_path).completed())
    assert journalled_before >= 2            # in-flight work may add one more

    resumed = Session.resume(wal_path, spec)
    multi = resumed.search(train)
    # the resumed run trained ONLY what the killed run hadn't journalled ...
    assert len(multi) == 6 - journalled_before
    assert len(SearchWAL(wal_path).completed()) == 6
    # ... and across both runs every config trained exactly once
    counts = {i: counting_estimator.trained.count(i) for i in range(6)}
    assert counts == {i: 1 for i in range(6)}, counts


# ---------------------------------------------------------------------------
# ExecutorBackend parity: both implementations satisfy the protocol and the
# same fault-recovery contract
# ---------------------------------------------------------------------------

def _estimator_task_runner(task, slice_mesh, data):
    """Mesh-slice runner that trains via the registry, like a real substrate."""
    return get_estimator(task.estimator).run(data, task.params)


def _make_backend(kind, n, failure_hook=None):
    if kind == "local":
        return LocalExecutorPool(n, failure_hook=failure_hook)
    return MeshSliceExecutorPool(
        task_runner=_estimator_task_runner,
        slices=[f"slice{i}" for i in range(n)],
        failure_hook=failure_hook,
    )


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_backend_satisfies_protocol(kind):
    backend = _make_backend(kind, 2)
    assert isinstance(backend, ExecutorBackend)
    assert backend.n_executors == 2
    assert backend.dead_executors == set()


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_backend_fault_recovery_parity(higgs_small, kind):
    """Kill executor 0 on its first task: the other executors absorb its
    queue and every task still completes — identical contract on both
    backends (the mesh pool historically lacked this)."""
    train, _ = higgs_small
    killed = []
    lock = threading.Lock()

    def failure_hook(eid, task):
        with lock:
            if eid == 0 and not killed:
                killed.append(task.task_id)
                raise ExecutorFailure(f"executor {eid} died")

    backend = _make_backend(kind, 3, failure_hook=failure_hook)
    tasks = enumerate_tasks(small_spaces())
    assignment = schedule(tasks, 3, policy="round_robin")
    results = list(backend.submit(assignment, train))
    assert killed, "hook never fired"
    assert backend.dead_executors == {0}
    assert sorted(r.task.task_id for r in results) == sorted(t.task_id for t in tasks)
    assert all(r.ok for r in results)
    assert all(backend.wal.is_done(t.task_id) for t in tasks)


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_backend_fault_recovery_dynamic_parity(higgs_small, kind):
    """Same contract under the dynamic pull-queue policy: a task claimed by
    a dying executor is handed back to survivors, never silently dropped."""
    train, _ = higgs_small
    killed = []
    lock = threading.Lock()

    def failure_hook(eid, task):
        with lock:
            if eid == 0 and not killed:
                killed.append(task.task_id)
                raise ExecutorFailure(f"executor {eid} died mid-task")

    backend = _make_backend(kind, 2, failure_hook=failure_hook)
    tasks = enumerate_tasks(small_spaces())
    results = list(backend.submit(schedule(tasks, 2, policy="dynamic"), train))
    assert killed, "hook never fired"
    assert sorted(r.task.task_id for r in results) == sorted(t.task_id for t in tasks)
    assert all(r.ok for r in results)


def test_resume_on_mesh_backend(higgs_small, tmp_path, counting_estimator):
    """Session.resume points a caller-supplied backend at the journal, so a
    mesh/LM search killed mid-way is resumable too."""
    train, _ = higgs_small
    wal_path = str(tmp_path / "wal.jsonl")
    spaces = [GridBuilder("counting").add_grid("i", list(range(5))).build()]
    spec = SearchSpec(spaces=spaces, n_executors=2, policy="round_robin",
                      wal_path=wal_path, max_tasks=2)
    killed_pool = MeshSliceExecutorPool(
        task_runner=_estimator_task_runner, slices=["s0", "s1"],
        wal=SearchWAL(wal_path))
    killed = Session(spec, backend=killed_pool)
    assert len(list(killed.results(train))) == 2

    fresh_pool = MeshSliceExecutorPool(        # note: no WAL of its own
        task_runner=_estimator_task_runner, slices=["s0", "s1"])
    resumed = Session.resume(wal_path, spec, backend=fresh_pool)
    resumed.search(train)
    counts = {i: counting_estimator.trained.count(i) for i in range(5)}
    assert counts == {i: 1 for i in range(5)}, counts
    assert len(SearchWAL(wal_path).completed()) == 5


@pytest.mark.parametrize("kind", ["local", "mesh"])
def test_backend_task_error_capture_parity(higgs_small, kind, counting_estimator):
    """A task-level exception becomes TaskResult.error on both backends and
    is NOT journalled (a resume retries it)."""
    train, _ = higgs_small

    class _Boom(Estimator):
        name = "boom"

        def train(self, data, params):
            raise ValueError("bad hyperparameters")

    register_estimator(_Boom)
    try:
        spaces = [GridBuilder("counting").add_grid("i", [0, 1]).build(),
                  GridBuilder("boom").build()]
        tasks = enumerate_tasks(spaces)
        backend = _make_backend(kind, 2)
        results = list(backend.submit(schedule(tasks, 2, policy="round_robin"), train))
        assert len(results) == 3
        errs = [r for r in results if not r.ok]
        assert len(errs) == 1 and "bad hyperparameters" in errs[0].error
        assert not backend.wal.is_done(errs[0].task.task_id)
        assert all(backend.wal.is_done(r.task.task_id) for r in results if r.ok)
    finally:
        unregister_estimator("boom")


def test_session_runs_on_mesh_backend(higgs_small):
    """The Session driver is backend-agnostic: the same spec runs unchanged
    on mesh-slice executors."""
    train, valid = higgs_small
    backend = MeshSliceExecutorPool(
        task_runner=_estimator_task_runner,
        slices=["slice0", "slice1"],
    )
    spec = SearchSpec(spaces=small_spaces(), n_executors=2,
                      profiler=SamplingProfiler(0.05))
    multi = Session.run(spec, train, backend=backend)
    assert len(multi) == 5
    assert multi.best(valid).score > 0.6
