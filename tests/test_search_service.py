"""Multi-tenant SearchService (DESIGN.md §3.5): fair-share arbitration,
admission control/backpressure, per-tenant artifact namespacing, streaming
parity with a plain Session, exact cache accounting across concurrent
sessions, the fleet-level CostModel prior, and WAL resume through the
service."""
import os
import threading

import numpy as np
import pytest

import repro.tabular  # noqa: F401 — registers estimators
from repro.core import (
    Estimator,
    GridBuilder,
    SearchSpec,
    TrainedModel,
    register_estimator,
    unregister_estimator,
)
from repro.core.data_format import PreparedDataCache
from repro.core.scheduler import FairShareArbiter
from repro.data.synthetic import make_higgs_like
from repro.serve import SearchService, ServiceSaturated


@pytest.fixture(scope="module")
def tiny_data():
    data = make_higgs_like(400, seed=7)
    train, valid = data.split((0.8, 0.2), seed=1)
    train, mu, sd = train.standardize()
    valid, _, _ = valid.standardize(mu, sd)
    return train, valid


class _Scored(TrainedModel):
    def __init__(self, c):
        self.c = c

    def predict_proba(self, x):
        return 1.0 / (1.0 + np.exp(-self.c * np.asarray(x)[:, 0]))


class _Toy(Estimator):
    name = "svc_toy"
    data_format = "dense_rows"
    trained: list = []
    gate: threading.Event | None = None

    def train(self, data, params):
        if type(self).gate is not None:
            assert type(self).gate.wait(20), "test gate never released"
        type(self).trained.append(dict(params))
        return _Scored(float(params.get("c", 1.0)))

    @staticmethod
    def estimate_cost(params, n_rows, n_features):
        return 1e-4 * n_rows * params.get("c", 1.0)


@pytest.fixture
def toy():
    _Toy.trained = []
    _Toy.gate = None
    register_estimator(_Toy)
    yield _Toy
    _Toy.gate = None
    unregister_estimator("svc_toy")


def _toy_spec(n=3, **kw):
    sp = GridBuilder("svc_toy").add_grid("c", [0.1 * (i + 1) for i in range(n)]).build()
    # analytic profiler: cold-task costing never trains, so _Toy.trained
    # counts are exactly the real training runs
    kw.setdefault("profiler", {"kind": "analytic"})
    return SearchSpec(spaces=[sp], n_executors=2, **kw)


# ---------------------------------------------------------------------------
# FairShareArbiter (unit)
# ---------------------------------------------------------------------------

def test_arbiter_interleaves_small_tenant_through_big_backlog():
    arb = FairShareArbiter()
    arb.ensure_tenant("big")
    arb.ensure_tenant("small")
    for i in range(6):
        arb.push("big", f"b{i}")
    for i in range(2):
        arb.push("small", f"s{i}")
    order = []
    while True:
        got = arb.pop()
        if got is None:
            break
        order.append(got[1])
    # equal weights: small's 2 units dispatch within the first 4 slots
    # instead of waiting behind big's 6 (the FIFO failure mode)
    assert set(order[:4]) >= {"s0", "s1"}
    assert len(order) == 8


def test_arbiter_fifo_mode_is_head_of_line():
    arb = FairShareArbiter(mode="fifo")
    arb.ensure_tenant("big")
    arb.ensure_tenant("small")
    for i in range(6):
        arb.push("big", f"b{i}")
    for i in range(2):
        arb.push("small", f"s{i}")
    order = [arb.pop()[1] for _ in range(8)]
    assert order == [f"b{i}" for i in range(6)] + ["s0", "s1"]


def test_arbiter_weights_bias_dispatch_cost():
    arb = FairShareArbiter()
    arb.ensure_tenant("heavy", weight=3.0)
    arb.ensure_tenant("light", weight=1.0)
    for i in range(40):
        arb.push("heavy", ("h", i), cost=1.0)
        arb.push("light", ("l", i), cost=1.0)
    first = [arb.pop()[0] for _ in range(40)]
    n_heavy = sum(1 for t in first if t == "heavy")
    # 3:1 weights -> ~30 of the first 40 dispatches go to heavy
    assert 27 <= n_heavy <= 33
    assert arb.share_drift < 0.1


def test_arbiter_discard_and_len():
    arb = FairShareArbiter()
    arb.ensure_tenant("t")
    for i in range(5):
        arb.push("t", i)
    assert len(arb) == 5
    assert arb.discard("t", lambda x: x % 2 == 0) == 3
    assert len(arb) == 2
    assert [arb.pop()[1] for _ in range(2)] == [1, 3]
    assert arb.pop() is None


def test_arbiter_rejects_bad_args():
    with pytest.raises(ValueError):
        FairShareArbiter(mode="lifo")
    arb = FairShareArbiter()
    with pytest.raises(ValueError):
        arb.ensure_tenant("t", weight=0.0)


# ---------------------------------------------------------------------------
# Streaming parity + namespaced artifacts
# ---------------------------------------------------------------------------

def test_service_streams_like_a_session(toy, tiny_data, tmp_path):
    train, valid = tiny_data
    svc = SearchService(n_executors=2, artifact_root=str(tmp_path),
                        prepared_cache=PreparedDataCache())
    try:
        h = svc.submit_search(_toy_spec(4), train, valid, tenant="alice")
        results = list(h.results())
        assert len(results) == 4
        assert all(r.ok for r in results)
        # executor-side scoring flowed through, exactly like a pool backend
        assert all(r.score is not None for r in results)
        assert h.stats.n_tasks == 4
        assert len(h.multi_model()) == 4
        assert h.state == "done"
        assert h.time_to_first_result is not None
        # results() is one-shot, like Session.results()
        with pytest.raises(RuntimeError):
            next(h.results())
    finally:
        svc.close()


def test_service_namespaces_default_artifacts_per_tenant(toy, tiny_data, tmp_path):
    """Satellite 1: two path-less concurrent sessions must never share a WAL
    (or its ``<wal>.cost.json``) — each gets <root>/<tenant>/<session>.wal."""
    train, _ = tiny_data
    svc = SearchService(n_executors=2, artifact_root=str(tmp_path),
                        prepared_cache=PreparedDataCache())
    try:
        spec = _toy_spec(2)
        h1 = svc.submit_search(spec, train, tenant="alice")
        h2 = svc.submit_search(spec, train, tenant="alice")
        h3 = svc.submit_search(spec, train, tenant="bob")
        paths = {h.session.spec.wal_path for h in (h1, h2, h3)}
        assert len(paths) == 3
        for h in (h1, h2, h3):
            wal = h.session.spec.wal_path
            assert wal == os.path.join(str(tmp_path), h.tenant,
                                       f"{h.session_id}.wal")
            assert h.session.spec.cost_model_path == wal + ".cost.json"
        for h in (h1, h2, h3):
            h.wait(60)
            assert os.path.exists(h.session.spec.wal_path)
    finally:
        svc.close()


def test_service_rejects_live_wal_collision(toy, tiny_data, tmp_path):
    train, _ = tiny_data
    gate = threading.Event()
    _Toy.gate = gate
    svc = SearchService(n_executors=1, prepared_cache=PreparedDataCache())
    try:
        wal = str(tmp_path / "shared.wal")
        h1 = svc.submit_search(_toy_spec(2, wal_path=wal), train, tenant="a")
        with pytest.raises(ValueError, match="collision"):
            svc.submit_search(_toy_spec(2, wal_path=wal), train, tenant="b")
        gate.set()
        assert h1.wait(60)
        # once the first session finished, the path is reusable
        h2 = svc.submit_search(_toy_spec(2, wal_path=wal), train, tenant="b")
        assert h2.wait(60)
    finally:
        _Toy.gate = None
        gate.set()
        svc.close()


# ---------------------------------------------------------------------------
# Admission control / backpressure
# ---------------------------------------------------------------------------

def test_admission_bounds_active_and_queued(toy, tiny_data):
    train, _ = tiny_data
    gate = threading.Event()
    _Toy.gate = gate
    svc = SearchService(n_executors=1, max_active=1, max_queued=1,
                        prepared_cache=PreparedDataCache())
    try:
        h1 = svc.submit_search(_toy_spec(2), train, tenant="a")
        h2 = svc.submit_search(_toy_spec(2), train, tenant="b")
        # slot busy (gate holds h1 mid-train) + queue full -> backpressure
        assert h1.state == "active" and h2.state == "queued"
        with pytest.raises(ServiceSaturated):
            svc.submit_search(_toy_spec(2), train, tenant="c")
        st = svc.stats()
        assert st.n_active == 1 and st.n_queued == 1
        gate.set()
        assert h1.wait(60) and h2.wait(60)
        # both sessions ran fully once the slot freed up
        assert len(list(h1.results())) == 2
        assert len(list(h2.results())) == 2
        assert h2.queue_wait_seconds > 0.0
    finally:
        _Toy.gate = None
        gate.set()
        svc.close()


def test_cancel_queued_session_never_starts(toy, tiny_data):
    train, _ = tiny_data
    gate = threading.Event()
    _Toy.gate = gate
    svc = SearchService(n_executors=1, max_active=1,
                        prepared_cache=PreparedDataCache())
    try:
        h1 = svc.submit_search(_toy_spec(1), train, tenant="a")
        h2 = svc.submit_search(_toy_spec(1), train, tenant="b")
        h2.cancel()
        gate.set()
        assert h1.wait(60) and h2.wait(60)
        assert h2.state == "cancelled"
        assert list(h2.results()) == []
        assert len(_Toy.trained) == 1           # b never trained anything
    finally:
        _Toy.gate = None
        gate.set()
        svc.close()


def test_close_rejects_new_submissions(toy, tiny_data):
    train, _ = tiny_data
    svc = SearchService(n_executors=1, prepared_cache=PreparedDataCache())
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit_search(_toy_spec(1), train, tenant="a")


# ---------------------------------------------------------------------------
# Exact per-tenant accounting across concurrent sessions (satellite 2)
# ---------------------------------------------------------------------------

def test_two_session_cache_accounting_is_exact(toy, tiny_data):
    train, valid = tiny_data
    pc = PreparedDataCache()
    svc = SearchService(n_executors=2, prepared_cache=pc)
    try:
        handles = [svc.submit_search(_toy_spec(4), train, valid,
                                     tenant=t, weight=w)
                   for t, w in (("alice", 2.0), ("bob", 1.0))]
        for h in handles:
            assert all(r.ok for r in h.results())
        hits, misses = pc.counters()
        snap = pc.tenant_counters()
        assert sum(v.get("hits", 0) for v in snap.values()) == hits
        assert sum(v.get("misses", 0) for v in snap.values()) == misses
        assert sum(v.get("bytes", 0) for v in snap.values()) == pc.bytes_built
        # both tenants actually touched the shared cache
        assert set(snap) >= {"alice", "bob"}
        # the train variant was BUILT once, process-wide: one tenant paid the
        # miss, every other prepare was a hit (eval variant adds one more)
        assert misses == 2                      # train + validate variants
        st = svc.stats()
        ts = st.per_tenant
        assert ts["alice"].prepared_hits + ts["bob"].prepared_hits == hits
        assert ts["alice"].n_results == ts["bob"].n_results == 4
        assert abs(ts["alice"].share_entitled - 2 / 3) < 1e-9
        assert "alice" in st.summary()
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Fleet-level CostModel prior
# ---------------------------------------------------------------------------

def test_fleet_prior_warms_new_tenants_first_plan(toy, tiny_data, tmp_path):
    train, _ = tiny_data
    svc = SearchService(n_executors=2, artifact_root=str(tmp_path),
                        prepared_cache=PreparedDataCache())
    try:
        h1 = svc.submit_search(_toy_spec(3), train, tenant="veteran")
        assert len(list(h1.results())) == 3
        # every observation wrote through to the fleet model
        assert svc.fleet_cost_model.n_observed >= 3
        # a brand-new tenant's FIRST plan priced tasks from the fleet prior
        # (n_model_estimates > 0 before it observed anything), profiling none
        h2 = svc.submit_search(_toy_spec(3), train, tenant="rookie")
        assert len(list(h2.results())) == 3
        assert h2.stats.n_model_estimates > 0
        assert h2.stats.n_profiled == 0
        # write-through kept per-session persistence intact and distinct
        cm_path = h2.session.spec.cost_model_path
        assert cm_path != h1.session.spec.cost_model_path
    finally:
        svc.close()
    # close() persisted the fleet for the next service instance
    fleet_file = os.path.join(str(tmp_path), "fleet.cost.json")
    assert os.path.exists(fleet_file)
    svc2 = SearchService(n_executors=1, artifact_root=str(tmp_path),
                         prepared_cache=PreparedDataCache())
    try:
        assert svc2.fleet_cost_model.n_observed >= 6
    finally:
        svc2.close()


# ---------------------------------------------------------------------------
# WAL resume through the service
# ---------------------------------------------------------------------------

def test_wal_resume_skips_done_tasks_through_service(toy, tiny_data, tmp_path):
    train, _ = tiny_data
    wal = str(tmp_path / "resume.wal")
    svc = SearchService(n_executors=2, prepared_cache=PreparedDataCache())
    try:
        h1 = svc.submit_search(_toy_spec(4, wal_path=wal), train, tenant="a")
        assert len(list(h1.results())) == 4
        n_first = len(_Toy.trained)
        assert n_first == 4
        # resubmit the SAME spec: the fresh session adopts the WAL and skips
        # every completed task — nothing retrains
        h2 = svc.submit_search(_toy_spec(4, wal_path=wal), train, tenant="a")
        h2.wait(60)
        assert len(_Toy.trained) == n_first
        assert h2.state == "done"
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Real estimators end-to-end (fused units through the shared workers)
# ---------------------------------------------------------------------------

def test_service_runs_fused_real_estimators(tiny_data, tmp_path):
    train, valid = tiny_data
    sp = GridBuilder("logreg").add_grid("c", [0.05, 0.3, 1.0]).add_grid(
        "steps", [40]).build()
    spec = SearchSpec(spaces=[sp], n_executors=2, fuse=True)
    svc = SearchService(n_executors=2, artifact_root=str(tmp_path),
                        prepared_cache=PreparedDataCache())
    try:
        h = svc.submit_search(spec, train, valid, tenant="alice")
        results = list(h.results())
        assert len(results) == 3
        assert all(r.ok and r.score is not None for r in results)
        # fusion actually happened on the shared workers
        assert any(r.batch_size > 1 for r in results)
        best = h.multi_model().best(valid)
        assert best.score > 0.5
    finally:
        svc.close()
