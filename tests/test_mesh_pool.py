"""MeshSliceExecutorPool scheduling semantics, tested WITHOUT devices:
stand-in slice handles + a recording task_runner exercise WAL resume,
per-task error capture, dynamic load balancing, failure re-queue, and
fused-batch unbatching/straggler recovery."""
import pytest

from repro.core import (
    ExecutorFailure,
    FusedBatch,
    MeshSliceExecutorPool,
    SearchWAL,
    TrainTask,
    schedule,
)


def mk_tasks(costs):
    return [TrainTask(task_id=i, estimator="stub", params={"i": i}, cost=c)
            for i, c in enumerate(costs)]


class RecordingRunner:
    """task_runner that logs (task_id, slice) and can fail on demand."""

    def __init__(self, errors=(), die_on=()):
        self.calls: list[tuple[int, object]] = []
        self.errors = set(errors)        # task_ids -> task-level exception
        self.die_on = set(die_on)        # (slice_label, task_id) -> slice death

    def __call__(self, task, slice_mesh, data):
        if (slice_mesh, task.task_id) in self.die_on:
            self.die_on.discard((slice_mesh, task.task_id))
            raise ExecutorFailure(f"{slice_mesh} died")
        self.calls.append((task.task_id, slice_mesh))
        if task.task_id in self.errors:
            raise ValueError(f"task {task.task_id} is poisoned")
        return f"model-{task.task_id}", 0.01


def test_requires_mesh_or_slices():
    with pytest.raises(ValueError):
        MeshSliceExecutorPool(task_runner=RecordingRunner())
    # task_runner is OPTIONAL since §3.3: slices alone build the
    # estimator-backed default pool (per-slice prepared-data placement)
    pool = MeshSliceExecutorPool(slices=["s0"])
    assert pool.task_runner is None and pool.n_executors == 1


def test_wal_resume_skips_done_tasks(tmp_path):
    wal_path = str(tmp_path / "wal.jsonl")
    tasks = mk_tasks([1.0] * 4)
    assignment = schedule(tasks, 2, policy="lpt")

    r1 = RecordingRunner()
    pool1 = MeshSliceExecutorPool(task_runner=r1, slices=["s0", "s1"],
                                  wal=SearchWAL(wal_path))
    results = pool1.run(assignment, data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3]
    assert len(r1.calls) == 4

    # fresh pool, same WAL file: nothing re-runs, nothing is yielded
    r2 = RecordingRunner()
    pool2 = MeshSliceExecutorPool(task_runner=r2, slices=["s0", "s1"],
                                  wal=SearchWAL(wal_path))
    assert pool2.run(assignment, data=None) == []
    assert r2.calls == []


def test_per_task_error_capture(tmp_path):
    wal_path = str(tmp_path / "wal.jsonl")
    tasks = mk_tasks([1.0] * 3)
    runner = RecordingRunner(errors={1})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0"],
                                 wal=SearchWAL(wal_path))
    results = pool.run(schedule(tasks, 1, policy="round_robin"), data=None)
    assert len(results) == 3
    by_id = {r.task.task_id: r for r in results}
    assert by_id[0].ok and by_id[2].ok
    assert not by_id[1].ok and "poisoned" in by_id[1].error
    assert pool.dead_executors == set()      # a bad task never kills the slice
    # failures stay out of the WAL → a resumed pool retries exactly task 1
    retry = RecordingRunner()
    pool2 = MeshSliceExecutorPool(task_runner=retry, slices=["s0"],
                                  wal=SearchWAL(wal_path))
    again = pool2.run(schedule(tasks, 1, policy="round_robin"), data=None)
    assert [r.task.task_id for r in again] == [1]
    assert again[0].ok


def test_dynamic_queue_assignment_balances_load():
    tasks = mk_tasks([8.0, 7.0, 2.0, 1.0, 1.0, 1.0])
    runner = RecordingRunner()
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"])
    results = pool.run(schedule(tasks, 2, policy="dynamic"), data=None)
    assert len(results) == 6
    loads = {"s0": 0.0, "s1": 0.0}
    for r in results:
        loads[pool.slices[r.executor_id]] += r.task.cost
    # least-loaded placement of longest-first tasks: loads end up 10 vs 10,
    # never the 17-vs-3 a naive contiguous split would give
    assert abs(loads["s0"] - loads["s1"]) <= max(t.cost for t in tasks)
    assert set(s for _, s in runner.calls) == {"s0", "s1"}


def test_dynamic_assignment_skips_wal_done(tmp_path):
    wal = SearchWAL(str(tmp_path / "wal.jsonl"))
    tasks = mk_tasks([3.0, 2.0, 1.0, 1.0])
    runner = RecordingRunner()
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"], wal=wal)
    first = pool.run(schedule(tasks[:2], 2, policy="dynamic"), data=None)
    assert len(first) == 2
    # re-submitting the full set only runs the two new tasks
    rest = pool.run(schedule(tasks, 2, policy="dynamic"), data=None)
    assert sorted(r.task.task_id for r in rest) == [2, 3]
    assert sorted(t for t, _ in runner.calls) == [0, 1, 2, 3]


def test_slice_failure_requeues_to_survivors():
    tasks = mk_tasks([1.0] * 6)
    runner = RecordingRunner(die_on={("s0", 0)})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1", "s2"])
    results = pool.run(schedule(tasks, 3, policy="round_robin"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3, 4, 5]
    assert all(r.ok for r in results)
    assert pool.dead_executors == {0}
    assert all(s != "s0" for _, s in runner.calls)   # survivors did everything


def test_last_survivor_dies_mid_requeue():
    """Slice 0 dies on its own queue; slice 1 finishes its queue, then dies
    on the FIRST re-queued task — the remaining stranded work must fall
    through to the driver, not crash the re-queue loop."""
    tasks = mk_tasks([1.0] * 6)
    # round_robin: s0 [0,1,2], s1 [3,4,5]; ("s1", 0) fires during re-queue
    runner = RecordingRunner(die_on={("s0", 0), ("s1", 0)})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"])
    results = pool.run(schedule(tasks, 2, policy="round_robin"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3, 4, 5]
    assert all(r.ok for r in results)
    assert pool.dead_executors == {0, 1}
    # tasks 0..2 were stranded twice and ran inline on the driver
    assert {r.executor_id for r in results if r.task.task_id in (0, 1, 2)} == {-1}


def test_all_slices_dead_falls_back_to_driver():
    tasks = mk_tasks([1.0] * 4)
    # each slice dies on the first task of its own queue (round_robin gives
    # s0 [0,1] and s1 [2,3]) → no survivors → driver-inline recovery
    runner = RecordingRunner(die_on={("s0", 0), ("s1", 2)})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"])
    results = pool.run(schedule(tasks, 2, policy="round_robin"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3]
    assert pool.dead_executors == {0, 1}
    assert {r.executor_id for r in results} == {-1}  # driver ran everything
    assert all(pool.wal.is_done(t.task_id) for t in tasks)


def test_streaming_yields_before_completion():
    tasks = mk_tasks([1.0] * 4)
    runner = RecordingRunner()
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"])
    stream = pool.submit(schedule(tasks, 2, policy="lpt"), data=None)
    first = next(stream)
    assert len(runner.calls) == 1            # exactly one task has run so far
    assert first.ok
    rest = list(stream)
    assert len(rest) == 3


# --------------------------------------------------------------------------
# Fused batches: one program per unit, unbatched results, stragglers.
# --------------------------------------------------------------------------

class BatchAwareRunner(RecordingRunner):
    """Runner that also accepts FusedBatch units (one call per unit)."""

    def __call__(self, task, slice_mesh, data):
        if isinstance(task, FusedBatch):
            if (slice_mesh, task.task_id) in self.die_on:
                self.die_on.discard((slice_mesh, task.task_id))
                raise ExecutorFailure(f"{slice_mesh} died")
            self.calls.append((task.task_id, slice_mesh))
            return [f"model-{m.task_id}" for m in task.tasks], 0.04 * task.batch_size
        return super().__call__(task, slice_mesh, data)


def mk_fused(costs, start=0):
    tasks = [TrainTask(task_id=start + i, estimator="stub", params={"i": i}, cost=c)
             for i, c in enumerate(costs)]
    return FusedBatch(tasks=tuple(tasks), signature=("stub", ()),
                      buckets=(0,) * len(tasks), cost=float(sum(costs)))


def test_fused_unit_unbatches_with_amortized_seconds(tmp_path):
    wal = SearchWAL(str(tmp_path / "wal.jsonl"))
    unit = mk_fused([1.0] * 4)
    runner = BatchAwareRunner()
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0"], wal=wal)
    results = pool.run(schedule([unit], 1, policy="lpt"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3]
    assert len(runner.calls) == 1            # ONE program for the whole unit
    assert all(r.batch_size == 4 for r in results)
    assert all(r.train_seconds == pytest.approx(0.04) for r in results)
    assert all(wal.is_done(t) for t in range(4))
    # resubmitting skips every journalled member without running anything
    again = MeshSliceExecutorPool(task_runner=BatchAwareRunner(),
                                  slices=["s0"], wal=SearchWAL(wal.path))
    assert again.run(schedule([unit], 1, policy="lpt"), data=None) == []


def test_fused_batch_error_becomes_per_member_errors():
    class ExplodingRunner(BatchAwareRunner):
        def __call__(self, task, slice_mesh, data):
            raise ValueError("batch is poisoned")

    pool = MeshSliceExecutorPool(task_runner=ExplodingRunner(), slices=["s0"])
    results = pool.run(schedule([mk_fused([1.0] * 3)], 1, policy="lpt"), data=None)
    assert len(results) == 3
    assert all(not r.ok and "poisoned" in r.error for r in results)
    assert pool.dead_executors == set()      # a bad batch never kills the slice


def test_fused_stragglers_survive_mid_stream_cancel(tmp_path):
    """Fault parity with LocalExecutorPool.drain_stragglers: a replanning
    driver that cancels the stream mid-unbatch must be able to collect the
    finished members it never saw — they are journalled, and losing their
    models would silently waste trained work."""
    wal = SearchWAL(str(tmp_path / "wal.jsonl"))
    unit = mk_fused([1.0] * 5)
    pool = MeshSliceExecutorPool(task_runner=BatchAwareRunner(),
                                 slices=["s0"], wal=wal)
    stream = pool.submit(schedule([unit], 1, policy="lpt"), data=None)
    seen = [next(stream), next(stream)]
    stream.close()                           # replan-style cancellation
    stragglers = pool.drain_stragglers()
    assert len(stragglers) == 3
    assert {r.task.task_id for r in seen} | {r.task.task_id for r in stragglers} \
        == {0, 1, 2, 3, 4}
    assert all(r.ok for r in stragglers)
    assert all(wal.is_done(t) for t in range(5))
    assert pool.drain_stragglers() == []     # buffer clears on read


def test_fused_unit_requeues_to_survivor_on_slice_death(tmp_path):
    """A slice dying ON a fused unit strands the whole unit; the survivor
    re-runs it as one program."""
    unit = mk_fused([1.0] * 3)
    single = TrainTask(task_id=99, estimator="stub", params={}, cost=1.0)
    runner = BatchAwareRunner(die_on={("s0", unit.task_id)})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"],
                                 wal=SearchWAL(str(tmp_path / "wal.jsonl")))
    results = pool.run(schedule([unit, single], 2, policy="lpt"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 99]
    assert all(r.ok for r in results)
    assert pool.dead_executors == {0}
    assert all(s == "s1" for _, s in runner.calls)   # survivor did everything


# --------------------------------------------------------------------------
# Pinning paths (§3.7): plans wider than the pool, and total executor loss.
# --------------------------------------------------------------------------

def test_excess_plan_queues_are_not_dropped():
    """A plan built for MORE executors than the pool has slices: the extra
    queues' tasks must still surface (the old zip() silently dropped them)."""
    tasks = mk_tasks([1.0] * 6)
    runner = RecordingRunner()
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"])
    # round_robin over 4 queues: q2=[2] and q3=[3] have no slice to run on
    results = pool.run(schedule(tasks, 4, policy="round_robin"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3, 4, 5]
    assert all(r.ok for r in results)
    assert pool.dead_executors == set()      # stranded ≠ dead
    assert {s for _, s in runner.calls} == {"s0", "s1"}


def test_driver_fallback_death_surfaces_typed_errors(tmp_path):
    """Every slice dead AND the driver-inline fallback dying too: stranded
    tasks surface as AllExecutorsLost error results — never vanish, never
    journal."""
    from repro.core import AllExecutorsLost  # noqa: F401 — typed error

    tasks = mk_tasks([1.0] * 4)
    # round_robin: s0 [0,1], s1 [2,3]; both die on their first task, then
    # the driver (slice handle "s0") dies again on tasks 1 and 3
    runner = RecordingRunner(
        die_on={("s0", 0), ("s1", 2), ("s0", 1), ("s0", 3)})
    pool = MeshSliceExecutorPool(task_runner=runner, slices=["s0", "s1"],
                                 wal=SearchWAL(str(tmp_path / "wal.jsonl")))
    results = pool.run(schedule(tasks, 2, policy="round_robin"), data=None)
    assert sorted(r.task.task_id for r in results) == [0, 1, 2, 3]
    assert pool.dead_executors == {0, 1}
    by_id = {r.task.task_id: r for r in results}
    assert by_id[0].ok and by_id[2].ok       # driver salvaged what it could
    for tid in (1, 3):
        assert not by_id[tid].ok
        assert "AllExecutorsLost" in by_id[tid].error
        assert not pool.wal.is_done(tid)     # failures stay out of the WAL
