"""Roofline analysis unit tests: HLO collective parsing + term math."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import count_params
from repro.roofline import analyze_compiled, model_flops, parse_collective_bytes
from repro.roofline.analysis import _shape_bytes, active_params


SYNTH_HLO = """
HloModule m
ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %p1 = bf16[4,256]{1,0} parameter(1)
  %ar = f32[16,128]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[1,4]<=[4]
  %ag = bf16[16,256]{1,0} all-gather(%p1), channel_id=2, dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%ar), channel_id=3, dimensions={0}
  %cp = f32[16,128]{1,0} collective-permute(%rs), channel_id=4
  %ars = f32[16,128]{1,0} all-reduce-start(%p0), channel_id=5
  %ard = f32[16,128]{1,0} all-reduce-done(%ars)
  ROOT %t = (f32[16,128]{1,0}) tuple(%cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[4,256]") == 4 * 256 * 2
    assert _shape_bytes("(f32[2]{0}, s32[3]{0})") == 8 + 12
    assert _shape_bytes("pred[]") == 1


def test_parse_collective_bytes_synthetic():
    got = parse_collective_bytes(SYNTH_HLO)
    f16_128 = 16 * 128 * 4
    assert got["all-reduce"] == 2 * f16_128        # %ar + %ars (done skipped)
    assert got["all-gather"] == 4 * 256 * 2
    assert got["reduce-scatter"] == f16_128        # operand %ar
    assert got["collective-permute"] == 4 * 128 * 4
    assert got["total"] == sum(got[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_parse_real_compiled_allreduce():
    """End-to-end on a real XLA compile (1 device → no collectives;
    the function still returns a well-formed dict)."""
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
    ).compile()
    got = parse_collective_bytes(c.as_text())
    assert got["total"] == 0


def test_model_flops_conventions():
    cfg = configs.get_config("tinyllama_1_1b")
    n = count_params(cfg)
    train = configs.SHAPES["train_4k"]
    dec = configs.SHAPES["decode_32k"]
    assert model_flops(cfg, train, n) == 6.0 * n * 4096 * 256
    assert model_flops(cfg, dec, n) == 2.0 * n * 128


def test_active_params_moe():
    cfg = configs.get_config("qwen3_moe_235b")
    n = count_params(cfg)
    act = active_params(cfg, n)
    # qwen3-235b has ~22B active ("A22B")
    assert 18e9 < act < 26e9, act / 1e9
    dense = configs.get_config("qwen2_1_5b")
    assert active_params(dense, 100) == 100


def test_analyze_compiled_smoke():
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
    ).compile()
    rep = analyze_compiled(c, arch="toy", shape=configs.SHAPES["train_4k"],
                           mesh_desc="1", n_devices=1)
    assert rep.flops_per_device == 2 * 256**3
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.step_time_s == max(rep.compute_s, rep.memory_s, rep.collective_s)
