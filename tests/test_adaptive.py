"""Adaptive search (DESIGN.md §3.6): resumable training parity, ASHA rungs
on the streaming Session, WAL mid-rung resume, and the Tuner API shims."""
import json
import warnings

import numpy as np
import pytest

import repro.tabular  # noqa: F401  (registers the four estimators)
from repro.core import (
    AshaController,
    Estimator,
    GridBuilder,
    ResumeState,
    RungTask,
    SamplingProfiler,
    SearchSpec,
    Session,
    SuccessiveHalvingTuner,
    TaskResult,
    TrainTask,
    Tuner,
    get_estimator,
    run_prepared,
    run_prepared_resumable,
)
from repro.core.cost_model import CostModel
from repro.core.grid import enumerate_tasks
from repro.core.tuner import GridSearchTuner, make_tuner

# family → (params, (rung budget, final budget)); budgets small enough to
# keep the whole module fast but big enough that a wrong carry would show
_FAMILIES = {
    "logreg": ({"c": 1.0, "lr": 0.05}, (20, 50)),
    "mlp": ({"network": "16_16", "learning_rate": 0.03, "batch_size": 64},
            (10, 30)),
    "gbdt": ({"eta": 0.3, "max_depth": 4, "max_bin": 32}, (3, 7)),
    "forest": ({"max_depth": 4}, (2, 5)),
}
#: tree families append rounds/trees to heap-layout stacks — bit-exact;
#: the Adam families rebuild the jitted program for the resumed segment, so
#: parity is numeric (observed ~1e-7, bound 1e-6 per the acceptance bar)
_BIT_EXACT = ("gbdt", "forest")


def _model_arrays(model) -> dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in vars(model).items()
            if isinstance(v, np.ndarray)}


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_resume_parity(family, higgs_small):
    """rung-k-then-resume-to-n matches straight-to-n: bit-exact for the
    tree families, <= 1e-6 on predictions for the Adam families."""
    train, valid = higgs_small
    est = get_estimator(family)
    params, (k, n) = _FAMILIES[family]
    assert est.budget_param is not None
    # straight run through the plain train path at the full budget
    plain, _, _ = run_prepared(est, train, {**params, est.budget_param: n})
    # rung at k, then resume to n from the carried state
    m_k, _, _, s_k = run_prepared_resumable(est, train, params, budget=k)
    assert isinstance(s_k, ResumeState) and s_k.budget == k
    m_n, _, _, s_n = run_prepared_resumable(est, train, params,
                                            budget=n, state=s_k)
    assert s_n.budget == n
    p_plain = plain.predict_proba(valid.x)
    p_chain = m_n.predict_proba(valid.x)
    if family in _BIT_EXACT:
        assert np.array_equal(p_plain, p_chain)
        a, b = _model_arrays(plain), _model_arrays(m_n)
        assert set(a) == set(b)
        for name in a:   # trees, thresholds, leaves: identical bit for bit
            assert np.array_equal(a[name], b[name]), name
    else:
        np.testing.assert_allclose(p_chain, p_plain, atol=1e-6)


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_resume_state_wire_roundtrip(family, higgs_small):
    """A ResumeState survives WAL journalling (JSON) bit-for-bit: resuming
    from the round-tripped state reproduces the direct resume exactly."""
    train, valid = higgs_small
    est = get_estimator(family)
    params, (k, n) = _FAMILIES[family]
    _, _, _, s_k = run_prepared_resumable(est, train, params, budget=k)
    wire = json.loads(json.dumps(s_k.to_wire()))      # through real JSON
    s_rt = ResumeState.from_wire(wire)
    direct, _, _, _ = run_prepared_resumable(est, train, params,
                                             budget=n, state=s_k)
    rehydrated, _, _, _ = run_prepared_resumable(est, train, params,
                                                 budget=n, state=s_rt)
    assert np.array_equal(direct.predict_proba(valid.x),
                          rehydrated.predict_proba(valid.x))


def test_default_train_resumable_falls_back_to_scratch():
    """Families without resume support still work under ASHA: the base
    implementation trains from scratch at the absolute budget."""

    class Stub(Estimator):
        name = "stub"
        data_format = "dense_rows"
        budget_param = "iters"

        def default_params(self):
            return {"iters": 5}

        def train(self, data, params):
            return dict(params)

    est = Stub()
    model, state = est.train_resumable(None, {"c": 2}, budget=7)
    assert model["iters"] == 7 and model["c"] == 2
    assert state is None              # nothing to carry — every rung is cold


# ---------------------------------------------------------------------------
# AshaController unit behaviour
# ---------------------------------------------------------------------------

def _space4():
    return GridBuilder("logreg").add_grid("c", [0.01, 0.1, 1.0, 10.0]).build()


def _ok(task, score, state=None):
    return TaskResult(task=task, model=None, train_seconds=0.1,
                      executor_id=0, score=score, resume_state=state)


def test_asha_promotes_top_fraction_and_carries_state():
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=20, max_budget=80, eta=2)
    wave = ctl.suggest()
    assert len(wave) == 4 and all(t.rung == 0 and t.budget == 20 for t in wave)
    states = {}
    for i, t in enumerate(wave):
        states[t.config_id] = ResumeState("logreg", 20, {"mark": np.float32(i)})
        ctl.report(_ok(t, 0.5 + 0.1 * i, states[t.config_id]))
    promo = ctl.suggest()
    assert len(promo) == 2            # ceil(4 / 2)
    assert all(isinstance(t, RungTask) and t.rung == 1 and t.budget == 40
               and t.prev_budget == 20 for t in promo)
    # top scorers by config, with their own carried states
    assert sorted(t.config_id for t in promo) == [2, 3]
    for t in promo:
        assert t.state is states[t.config_id]
    # budget params carry the ABSOLUTE budget (cache-key stability)
    assert all(t.params["steps"] == 40 for t in promo)


def test_asha_errors_retire_configs():
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=10, max_budget=40, eta=2)
    wave = ctl.suggest()
    for t in wave[:2]:
        ctl.report(TaskResult(task=t, model=None, train_seconds=0.0,
                              executor_id=0, error="boom"))
    for t in wave[2:]:
        ctl.report(_ok(t, 0.9))
    promo = ctl.suggest()
    # errored configs never promote; survivors ladder on
    assert {t.config_id for t in promo} <= {2, 3} and promo


def test_asha_ladder_terminates_at_cap():
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=20, max_budget=100, eta=2)
    total = []
    while True:
        wave = ctl.suggest()
        if not wave:
            break
        total.extend(wave)
        for t in wave:
            ctl.report(_ok(t, 0.5 + 0.01 * t.config_id))
    # budgets 20/40/80/100 → rungs of 4, 2, 1, 1
    assert [t.budget for t in total] == [20] * 4 + [40] * 2 + [80, 100]
    assert ctl.suggest() == []        # stays done


def test_asha_suggest_budget_hint_defers_without_losing_work():
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=20, max_budget=40, eta=2)
    first = ctl.suggest(2)
    assert len(first) == 2
    rest = ctl.suggest()
    assert len(rest) == 2             # the capped remainder re-emerges
    assert {t.config_id for t in first} | {t.config_id for t in rest} \
        == {0, 1, 2, 3}


def test_kill_candidates_and_straggler_unkill():
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=10, max_budget=40, eta=2,
                         early_kill=0.5)
    wave = ctl.suggest()
    assert ctl.kill_candidates() == set()     # nothing completed yet
    for t in wave[:2]:
        ctl.report(_ok(t, 0.9))
    kills = ctl.kill_candidates()
    assert kills == {wave[2].task_id, wave[3].task_id}
    assert ctl.kill_candidates() == set()     # idempotent
    # a straggler that finishes anyway is un-killed and competes again
    ctl.report(_ok(wave[2], 0.99))
    promo = ctl.suggest()
    assert wave[2].config_id in {t.config_id for t in promo}


def test_successive_halving_is_asha_without_kills():
    tuner = SuccessiveHalvingTuner([_space4()], budget_param="steps",
                                   base_budget=20, max_budget=100, eta=2)
    assert isinstance(tuner, AshaController)
    assert tuner.kill_candidates() == set()


# ---------------------------------------------------------------------------
# Deprecation shims (one release)
# ---------------------------------------------------------------------------

def test_propose_observe_shims_forward_with_warning():
    tuner = GridSearchTuner([_space4()])
    with pytest.warns(DeprecationWarning):
        batch = tuner.propose()
    assert len(batch) == 4
    ctl = AshaController([_space4()], budget_param="steps",
                         base_budget=20, max_budget=40, eta=2)
    wave = ctl.suggest()
    with pytest.warns(DeprecationWarning):
        ctl.observe([(t, 0.5 + 0.1 * t.config_id) for t in wave])
    assert len(ctl.suggest()) == 2    # the pairs reached report()


def test_legacy_tuner_subclass_bridged_through_session(higgs_small):
    """A pre-rung subclass (propose/observe only) still drives a Session."""
    train, valid = higgs_small

    class Legacy(Tuner):
        def __init__(self):
            self.tasks = enumerate_tasks([_space4()])
            self.rounds = 0
            self.seen = []

        @property
        def is_dynamic(self):
            return True

        def propose(self):
            if self.rounds >= 2:
                return []
            self.rounds += 1
            half = len(self.tasks) // 2
            lo = (self.rounds - 1) * half
            return self.tasks[lo:lo + half]

        def observe(self, pairs):
            self.seen.extend(pairs)

    tuner = Legacy()
    spec = SearchSpec(spaces=[_space4()], n_executors=2, tuner=tuner,
                      profiler=SamplingProfiler(0.2))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        results = list(Session(spec).results(train, valid))
    assert len(results) == 4
    # every round's scores were flushed through observe() (round 1's before
    # round 2 proposed; round 2's on the terminal suggest)
    assert len(tuner.seen) == 4


# ---------------------------------------------------------------------------
# Declarative tuner config on SearchSpec
# ---------------------------------------------------------------------------

def test_spec_tuner_kind_validation():
    sp = _space4()
    with pytest.raises(ValueError, match="unknown tuner"):
        SearchSpec(spaces=[sp], tuner="simulated_annealing")
    with pytest.raises(ValueError):   # probe-construct: missing budgets
        SearchSpec(spaces=[sp], tuner="asha")
    with pytest.raises(ValueError):   # probe-construct: bad eta
        SearchSpec(spaces=[sp], tuner="asha",
                   tuner_args={"budget_param": "steps", "base_budget": 10,
                               "max_budget": 40, "eta": 1})
    with pytest.raises(ValueError, match="tuner_args"):
        SearchSpec(spaces=[sp], tuner_args={"eta": 2})
    spec = SearchSpec(spaces=[sp], tuner="asha",
                      tuner_args={"budget_param": "steps", "base_budget": 10,
                                  "max_budget": 40})
    assert isinstance(spec.build_tuner(), AshaController)
    # each build materialises a FRESH controller (resume safety)
    assert spec.build_tuner() is not spec.build_tuner()


def test_make_tuner_registry():
    with pytest.raises(ValueError, match="unknown tuner kind"):
        make_tuner("nope", [_space4()])
    t = make_tuner("asha", [_space4()], budget_param="steps",
                   base_budget=10, max_budget=40)
    assert isinstance(t, AshaController)


# ---------------------------------------------------------------------------
# CostModel: rungs observed/estimated at their INCREMENT
# ---------------------------------------------------------------------------

def test_cost_model_buckets_rungs_by_increment():
    cm = CostModel()
    # a plain 180-round task observed once: the 2^7-ish bucket
    full = TrainTask(task_id=1, estimator="gbdt", params={"round": 180})
    cm.observe(full, seconds=2.0, n_rows=1000)
    # an absolute-270 task in the 2^8 bucket, much slower
    big = TrainTask(task_id=2, estimator="gbdt", params={"round": 270})
    cm.observe(big, seconds=3.5, n_rows=1000)
    # a rung at absolute budget 270 resuming from 90 runs a 180-round
    # increment — it must read the 180 bucket, not the 270 one
    rung = RungTask(task_id=900, estimator="gbdt", params={"round": 270},
                    config_id=0, rung=2, budget=270, prev_budget=90,
                    budget_param="round")
    assert cm.estimate(rung, 1000) == pytest.approx(2.0)
    assert cm.estimate(big, 1000) == pytest.approx(3.5)
    # observing the rung feeds the increment bucket too (blended law)
    cm.observe(rung, seconds=2.2, n_rows=1000)
    assert 2.0 < cm.estimate(full, 1000) < 2.2
    # eval laws stay on ABSOLUTE params: scoring depends on the model
    # produced (all 270 trees), not the increment trained
    cm.observe_eval(big, seconds=0.5, n_rows=500)
    assert cm.predict_eval(rung, 500) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# End to end: ASHA on the streaming Session, WAL mid-rung resume
# ---------------------------------------------------------------------------

_ASHA_ARGS = {"budget_param": "steps", "base_budget": 20,
              "max_budget": 100, "eta": 2}


def test_asha_session_streams_rungs(higgs_small):
    train, valid = higgs_small
    spec = SearchSpec(spaces=[_space4()], n_executors=2, tuner="asha",
                      tuner_args=_ASHA_ARGS, profiler=SamplingProfiler(0.1))
    session = Session(spec)
    results = list(session.results(train, valid))
    # budgets 20/40/80/100 → rungs of 4, 2, 1, 1
    assert len(results) == 8
    assert all(isinstance(r.task, RungTask) for r in results)
    assert all(r.ok and r.score is not None for r in results)
    # losers are killed at the rung: the work actually trained is the sum
    # of INCREMENTS, far below the exhaustive grid's 4 x 100 steps
    spent = sum(r.task.budget - r.task.prev_budget for r in results)
    assert spent < 4 * 100 / 2
    # the ladder reached the cap, and promotion followed the scores: the
    # rung-1 members are exactly the top-2 rung-0 configs by streamed score
    deepest = max(results, key=lambda r: r.task.rung)
    assert deepest.task.budget == 100
    rung0 = sorted((r for r in results if r.task.rung == 0),
                   key=lambda r: (-r.score, r.task.config_id))
    top2 = {r.task.config_id for r in rung0[:2]}
    assert {r.task.config_id for r in results if r.task.rung == 1} == top2
    # promoted rungs actually resumed (warm states journalled per result)
    assert all(r.resume_state is not None for r in results)


def test_asha_session_resumes_mid_ladder_from_wal(tmp_path, higgs_small):
    train, valid = higgs_small
    wal = str(tmp_path / "asha.wal")
    spec = SearchSpec(spaces=[_space4()], n_executors=2, tuner="asha",
                      tuner_args=_ASHA_ARGS, profiler=SamplingProfiler(0.1),
                      wal_path=wal, max_tasks=4)
    first = Session(spec)
    got = list(first.results(train, valid))
    assert first.stop_reason == "max_tasks" and len(got) == 4
    assert all(r.task.rung == 0 for r in got)
    # resume with the SAME declarative spec: the fresh controller replays
    # rung 0 from the WAL (scores + carried states) and runs only the
    # remaining rungs — from-scratch budgets would differ numerically
    second = Session.resume(wal, spec)
    rest = list(second.results(train, valid))
    assert len(rest) == 4 and all(r.task.rung >= 1 for r in rest)
    assert all(r.ok and r.score is not None for r in rest)
    # nothing re-trained: task ids are disjoint from the first run's
    assert {r.task.task_id for r in got}.isdisjoint(
        {r.task.task_id for r in rest})
    # the resumed ladder still reaches the cap
    assert max(r.task.budget for r in rest) == 100
    # parity with an uninterrupted run on the same data: same final score
    solo = Session(spec.replace(wal_path=None, max_tasks=None))
    solo_results = list(solo.results(train, valid))
    best_resumed = max(r.score for r in got + rest)
    best_solo = max(r.score for r in solo_results)
    assert best_resumed == pytest.approx(best_solo, abs=1e-6)


def test_asha_with_early_kill_completes(higgs_small):
    """early_kill armed end-to-end: the session completes, every reported
    result is consistent, and the ladder still reaches the cap."""
    train, valid = higgs_small
    spec = SearchSpec(spaces=[_space4()], n_executors=2, tuner="asha",
                      tuner_args={**_ASHA_ARGS, "early_kill": 0.5},
                      profiler=SamplingProfiler(0.1))
    session = Session(spec)
    results = list(session.results(train, valid))
    assert results and all(r.ok for r in results)
    assert max(r.task.budget for r in results) == 100
    assert session.stats.n_rung_kills >= 0
